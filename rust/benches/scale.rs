//! SCALE — scheduling hot-path throughput at production scale.
//!
//! Generates synthetic HTC scenarios (1k/5k/10k nodes spread over 2–8
//! sites, 100k–1M single/dual-slot jobs in four submission blocks) and
//! replays them three ways *in the same process* so the speedups are
//! apples to apples:
//!
//! * `indexed` vs `naive-reference` — one global event queue against
//!   the indexed / naive LRMS core (the PR-1 scheduling comparison),
//! * `sharded` — the same workload split into per-site shards: the
//!   single-queue engine (serial deterministic merge) vs the parallel
//!   windowed engine of `evhc::sim::shard`, with an equality assert
//!   that both replays produced identical per-site outcomes,
//! * `stealing` — skewed multi-site worlds (one hot site carrying
//!   `hot_mul`× the jobs of a cold site): the single-queue engine vs
//!   the chunked parallel engine vs the work-stealing engine, with
//!   digest equality asserts between all three, plus the per-shard
//!   metrics story — in-memory recorder bytes vs streaming spill-file
//!   bytes, with a byte-identical merged-figure assert between the two
//!   recording paths,
//! * `cluster` — the **real paper use case** (site-partitioned
//!   `HybridCluster`) at 1k/5k/10k nodes over 4–8 sites, replayed
//!   through all three engines (`Serial`/`Sharded`/`Stealing`) with
//!   cross-engine digest + figure byte-equality asserts, plus the
//!   spill path with figures rendered straight from the spill streams,
//! * `trace` — the streaming trace frontend: a generated
//!   burst/diurnal arrival process (`EVHC_TRACE_JOBS` jobs; 20k quick,
//!   1M full, 10M if you ask) replayed through a bounded ingest
//!   watermark and spill-mode recorders on all three engines —
//!   jobs/sec and RSS per engine, with cross-engine digest equality,
//!   100% completion, the `peak_buffered_jobs ≤ watermark + block`
//!   memory bound and a `SynthSource ≡ Workload` digest compare
//!   asserted in-bench,
//! * `broker` — full-cluster elasticity runs over 2–8 sites, policy ×
//!   scenario (spot-preemption waves, site outages, price spikes):
//!   cost, makespan and preempted-job recovery per combination, each
//!   replayed twice with a determinism assert,
//! * `chaos` — WAN fault injection on the paper use case (1% / 5%
//!   message loss, a mid-run 900 s partition): recovery overhead vs a
//!   fault-free reference and completed-jobs/sec, with cross-engine
//!   digest equality asserted in-bench (diffed warn-only by
//!   `bench_compare` — the rows are wall-clock sensitive),
//! * `chaos_sweep` — the recovery-overhead frontier: `RetryPolicy`
//!   knobs (backoff base, failover threshold, breaker threshold) ×
//!   WAN loss severity, bounded by `EVHC_SWEEP_POINTS`, plus the
//!   adaptive-placement headline — health-aware placement must beat
//!   static SLA ranking under sustained loss (asserted in-bench),
//! * `perf_profile` — the engine profiler on the paper use case: how
//!   the parallel engines split wall time between shard windows, the
//!   control barrier and injector waiting, plus the tracing-overhead
//!   ratio (events/sec with observability on vs off) with in-bench
//!   digest-neutrality and trace-validity asserts.
//!
//! Results are written to `BENCH_scale.json` at the repo root so future
//! PRs accumulate a perf trajectory (`ci.sh` diffs it against the
//! committed `BENCH_baseline.json` and, with `EVHC_BENCH_GATE=1`, fails
//! on events/sec regressions beyond 15%).
//!
//!     cargo bench --bench scale              # full suite (~10k nodes)
//!     EVHC_SCALE_BENCH_QUICK=1 cargo bench --bench scale   # CI mode

use std::path::Path;
use std::time::Instant;

use evhc::api::json::Json;
use evhc::broker::{PolicyKind, ScenarioPlan};
use evhc::cluster::{DispatchMode, Engine, HybridCluster, RetryPolicy,
                    RunConfig, RunReport, WanFaultPlan};
use evhc::ids::NodeNames;
use evhc::orchestrator::Sla;
use evhc::lrms::core::{BatchCore, Placement};
use evhc::lrms::JobId;
use evhc::metrics::{DisplayState, Recorder, ShardSink, SpillFiles};
use evhc::obs::{EngineProfile, ObsConfig};
use evhc::sim::shard::{default_threads, run_sharded, run_sharded_serial,
                       run_sharded_stealing, ControlPlane, SiteCtx,
                       SiteShard, StealConfig};
use evhc::sim::{EventQueue, ShardEvent, ShardKey, ShardedQueue, SimTime};
use evhc::util::bench::section;
use evhc::util::prng::Prng;
use evhc::workload::trace::{ArrivalGen, ArrivalProfile, SynthSource};

struct Scenario {
    name: &'static str,
    nodes: u32,
    sites: u32,
    jobs: u32,
    slots_per_node: u32,
    /// Run the naive reference scheduler too (skipped at 10k nodes —
    /// O(jobs·nodes) makes it minutes-long there).
    with_naive: bool,
}

#[derive(Debug, Clone, Copy)]
struct Measured {
    events: u64,
    wall_s: f64,
    events_per_sec: f64,
    ms_per_tick: f64,
    completed: u32,
}

enum Ev {
    SubmitBlock(u32),
    JobDone(JobId),
}

/// Replay one synthetic scenario to completion on `core`.
fn run_scenario(core: &mut BatchCore, sc: &Scenario, seed: u64)
    -> Measured {
    let mut rng = Prng::new(seed);
    for i in 0..sc.nodes {
        let site = i % sc.sites;
        core.register_node(&format!("s{site}-wn-{i}"), sc.slots_per_node,
                           SimTime(0.0));
    }
    let mut q: EventQueue<Ev> = EventQueue::new();
    let blocks = 4u32;
    for b in 0..blocks {
        let n = sc.jobs / blocks
            + if b == 0 { sc.jobs % blocks } else { 0 };
        q.schedule_at(SimTime(b as f64 * 900.0), Ev::SubmitBlock(n));
    }
    let mut completed = 0u32;
    let mut ticks = 0u64;
    let mut tick_secs = 0.0;
    let wall = Instant::now();
    while let Some((t, ev)) = q.pop() {
        match ev {
            Ev::SubmitBlock(n) => {
                for i in 0..n {
                    // Mixed 1/2-slot jobs; empty name → no allocation.
                    core.submit("", 1 + (i % 2), t);
                }
            }
            Ev::JobDone(j) => {
                let _ = core.on_job_finished(j, true, t);
                completed += 1;
            }
        }
        let t0 = Instant::now();
        let assigned = core.schedule(t);
        tick_secs += t0.elapsed().as_secs_f64();
        ticks += 1;
        for (job, _node) in assigned {
            q.schedule_in(15.0 + rng.next_f64() * 5.0, Ev::JobDone(job));
        }
        if completed >= sc.jobs {
            break;
        }
    }
    let wall_s = wall.elapsed().as_secs_f64();
    let events = q.dispatched();
    Measured {
        events,
        wall_s,
        events_per_sec: events as f64 / wall_s.max(1e-9),
        ms_per_tick: tick_secs * 1e3 / ticks.max(1) as f64,
        completed,
    }
}

// ---------------------------------------------------------------------
// Sharded replay: the same workload split into per-site shards.
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
enum SEv {
    /// Control shard: fan one submission block out to every site.
    Block { jobs_per_site: u32 },
    /// Site shard: submit `n` jobs at this site.
    Submit { site: u32, n: u32 },
    /// Site shard: a job finished at this site.
    Done { site: u32, job: JobId },
}

impl ShardEvent for SEv {
    fn shard_key(&self) -> ShardKey {
        match self {
            SEv::Block { .. } => ShardKey::Control,
            SEv::Submit { site, .. } | SEv::Done { site, .. } => {
                ShardKey::Site(*site)
            }
        }
    }
}

/// One cloud site's shard: its own LRMS core, rng, counters and —
/// in the stealing/metrics section — a recorder (in-memory or
/// streaming to spill files).
struct SiteSim {
    site: u32,
    core: BatchCore,
    rng: Prng,
    completed: u32,
    ticks: u64,
    tick_secs: f64,
    rec: Option<Recorder>,
}

impl SiteShard for SiteSim {
    type Event = SEv;

    fn handle(&mut self, t: SimTime, ev: SEv, ctx: &mut SiteCtx<'_, SEv>) {
        match ev {
            SEv::Submit { n, .. } => {
                for i in 0..n {
                    // Mixed 1/2-slot jobs; empty name → no allocation.
                    self.core.submit("", 1 + (i % 2), t);
                }
            }
            SEv::Done { job, .. } => {
                let _ = self.core.on_job_finished(job, true, t);
                self.completed += 1;
                if let Some(rec) = self.rec.as_mut() {
                    if let Some(j) = self.core.job(job) {
                        if let (Some(node), Some(s), Some(e)) =
                            (j.node, j.started_at, j.finished_at)
                        {
                            let name = self
                                .core
                                .node_name(node)
                                .expect("assigned node");
                            rec.job_run(&name, s, e);
                        }
                    }
                }
            }
            SEv::Block { .. } => unreachable!("control event in site shard"),
        }
        let t0 = Instant::now();
        let assigned = self.core.schedule(t);
        self.tick_secs += t0.elapsed().as_secs_f64();
        self.ticks += 1;
        for (job, node) in assigned {
            if let Some(rec) = self.rec.as_mut() {
                let name =
                    self.core.node_name(node).expect("assigned node");
                rec.node_state(t, &name, DisplayState::Used);
            }
            ctx.schedule_in(15.0 + self.rng.next_f64() * 5.0, SEv::Done {
                site: self.site,
                job,
            });
        }
    }
}

/// Control plane: only feeds submission blocks; sites never talk back,
/// so the lookahead is unbounded and windows stretch block to block.
struct BlockFeeder {
    sites: u32,
}

impl ControlPlane for BlockFeeder {
    type Site = SiteSim;

    fn handle(&mut self, _sites: &mut [SiteSim], t: SimTime, ev: SEv,
              q: &mut ShardedQueue<SEv>) {
        if let SEv::Block { jobs_per_site } = ev {
            for s in 0..self.sites {
                q.schedule_at(t, SEv::Submit { site: s, n: jobs_per_site });
            }
        }
    }
}

fn sharded_world(sc: &Scenario, seed: u64)
    -> (BlockFeeder, Vec<SiteSim>, ShardedQueue<SEv>) {
    let mut sites = Vec::new();
    for s in 0..sc.sites {
        let mut core = BatchCore::new(Placement::PackFirstFit);
        let mut i = s;
        while i < sc.nodes {
            core.register_node(&format!("s{s}-wn-{i}"), sc.slots_per_node,
                               SimTime(0.0));
            i += sc.sites;
        }
        sites.push(SiteSim {
            site: s,
            core,
            rng: Prng::new(seed ^ (s as u64 + 1).wrapping_mul(0x9E37)),
            completed: 0,
            ticks: 0,
            tick_secs: 0.0,
            rec: None,
        });
    }
    let mut q: ShardedQueue<SEv> = ShardedQueue::new(sc.sites as usize);
    let jps = sc.jobs / sc.sites;
    let blocks = 4u32;
    for b in 0..blocks {
        let n = jps / blocks + if b == 0 { jps % blocks } else { 0 };
        q.schedule_at(SimTime(b as f64 * 900.0),
                      SEv::Block { jobs_per_site: n });
    }
    (BlockFeeder { sites: sc.sites }, sites, q)
}

/// Per-site outcome digest used to assert single-queue ≡ parallel.
type SiteDigest = Vec<(u32, usize, u32, u64)>;

fn run_sharded_scenario(sc: &Scenario, seed: u64, parallel: bool,
                        threads: usize) -> (Measured, SiteDigest) {
    let (mut feeder, mut sites, mut q) = sharded_world(sc, seed);
    let wall = Instant::now();
    if parallel {
        run_sharded(&mut feeder, &mut sites, &mut q,
                    SimTime(f64::INFINITY), threads);
    } else {
        run_sharded_serial(&mut feeder, &mut sites, &mut q,
                           SimTime(f64::INFINITY));
    }
    let wall_s = wall.elapsed().as_secs_f64();
    let events = q.dispatched();
    let completed: u32 = sites.iter().map(|s| s.completed).sum();
    let expected = (sc.jobs / sc.sites) * sc.sites;
    assert_eq!(completed, expected, "sharded run must drain the workload");
    let ticks: u64 = sites.iter().map(|s| s.ticks).sum();
    let tick_secs: f64 = sites.iter().map(|s| s.tick_secs).sum();
    let digest = sites
        .iter()
        .map(|s| (s.completed, s.core.pending(), s.core.free_slots(),
                  s.ticks))
        .collect();
    let m = Measured {
        events,
        wall_s,
        events_per_sec: events as f64 / wall_s.max(1e-9),
        ms_per_tick: tick_secs * 1e3 / ticks.max(1) as f64,
        completed,
    };
    (m, digest)
}

// ---------------------------------------------------------------------
// Work-stealing on skewed worlds + streaming per-shard metrics.
// ---------------------------------------------------------------------

/// A skewed multi-site scenario: site 0 (the hot site) receives
/// `hot_mul`× the jobs of each cold site, reproducing the
/// one-hot-back-end mix that serializes the chunked parallel engine.
struct SkewSpec {
    name: &'static str,
    cold_sites: u32,
    hot_mul: u32,
    nodes_per_site: u32,
    slots_per_node: u32,
    cold_jobs_per_block: u32,
    blocks: u32,
}

impl SkewSpec {
    fn sites(&self) -> u32 {
        self.cold_sites + 1
    }

    fn total_jobs(&self) -> u32 {
        self.blocks * self.cold_jobs_per_block
            * (self.cold_sites + self.hot_mul)
    }
}

/// Control plane for skewed worlds: fans each block out with the hot
/// multiplier applied to site 0. Sites never talk back (unbounded
/// lookahead, block-to-block windows).
struct SkewFeeder {
    sites: u32,
    hot_mul: u32,
}

impl ControlPlane for SkewFeeder {
    type Site = SiteSim;

    fn handle(&mut self, _sites: &mut [SiteSim], t: SimTime, ev: SEv,
              q: &mut ShardedQueue<SEv>) {
        if let SEv::Block { jobs_per_site } = ev {
            for s in 0..self.sites {
                let n = if s == 0 {
                    jobs_per_site * self.hot_mul
                } else {
                    jobs_per_site
                };
                q.schedule_at(t, SEv::Submit { site: s, n });
            }
        }
    }
}

/// Build a skewed world; every site records (in memory, or streaming
/// to spill files under `spill_dir` when given).
fn skew_world(sc: &SkewSpec, seed: u64, spill_dir: Option<&Path>)
    -> (SkewFeeder, Vec<SiteSim>, ShardedQueue<SEv>) {
    let mut sites = Vec::new();
    for s in 0..sc.sites() {
        let mut core = BatchCore::new(Placement::PackFirstFit);
        for k in 0..sc.nodes_per_site {
            core.register_node(&format!("s{s}-wn-{k}"), sc.slots_per_node,
                               SimTime(0.0));
        }
        let rec = match spill_dir {
            None => Recorder::new(),
            Some(dir) => Recorder::with_spill(
                NodeNames::new(),
                ShardSink::create(dir, s).expect("spill sink"),
            ),
        };
        sites.push(SiteSim {
            site: s,
            core,
            rng: Prng::new(seed ^ (s as u64 + 1).wrapping_mul(0x9E37)),
            completed: 0,
            ticks: 0,
            tick_secs: 0.0,
            rec: Some(rec),
        });
    }
    let mut q: ShardedQueue<SEv> = ShardedQueue::new(sc.sites() as usize);
    for b in 0..sc.blocks {
        q.schedule_at(SimTime(b as f64 * 900.0),
                      SEv::Block { jobs_per_site: sc.cold_jobs_per_block });
    }
    (SkewFeeder { sites: sc.sites(), hot_mul: sc.hot_mul }, sites, q)
}

enum SkewEngine {
    SingleQueue,
    Parallel(usize),
    Stealing(StealConfig),
}

fn run_skew(sc: &SkewSpec, seed: u64, engine: &SkewEngine,
            spill_dir: Option<&Path>)
    -> (Measured, SiteDigest, Vec<Recorder>) {
    let (mut feeder, mut sites, mut q) = skew_world(sc, seed, spill_dir);
    let wall = Instant::now();
    match engine {
        SkewEngine::SingleQueue => {
            run_sharded_serial(&mut feeder, &mut sites, &mut q,
                               SimTime(f64::INFINITY));
        }
        SkewEngine::Parallel(threads) => {
            run_sharded(&mut feeder, &mut sites, &mut q,
                        SimTime(f64::INFINITY), *threads);
        }
        SkewEngine::Stealing(cfg) => {
            run_sharded_stealing(&mut feeder, &mut sites, &mut q,
                                 SimTime(f64::INFINITY), *cfg);
        }
    }
    let wall_s = wall.elapsed().as_secs_f64();
    let events = q.dispatched();
    let completed: u32 = sites.iter().map(|s| s.completed).sum();
    assert_eq!(completed, sc.total_jobs(),
               "skew run must drain the workload");
    let ticks: u64 = sites.iter().map(|s| s.ticks).sum();
    let tick_secs: f64 = sites.iter().map(|s| s.tick_secs).sum();
    let digest = sites
        .iter()
        .map(|s| (s.completed, s.core.pending(), s.core.free_slots(),
                  s.ticks))
        .collect();
    let recs = sites
        .into_iter()
        .map(|s| s.rec.expect("skew sites record"))
        .collect();
    let m = Measured {
        events,
        wall_s,
        events_per_sec: events as f64 / wall_s.max(1e-9),
        ms_per_tick: tick_secs * 1e3 / ticks.max(1) as f64,
        completed,
    };
    (m, digest, recs)
}

fn stealing_section(quick: bool) -> Json {
    let specs: Vec<SkewSpec> = if quick {
        vec![SkewSpec {
            name: "skew10-7sites", cold_sites: 6, hot_mul: 10,
            nodes_per_site: 40, slots_per_node: 2,
            cold_jobs_per_block: 500, blocks: 4,
        }]
    } else {
        vec![
            SkewSpec {
                name: "skew8-8sites", cold_sites: 7, hot_mul: 8,
                nodes_per_site: 100, slots_per_node: 2,
                cold_jobs_per_block: 3000, blocks: 4,
            },
            SkewSpec {
                name: "skew24-4sites", cold_sites: 3, hot_mul: 24,
                nodes_per_site: 60, slots_per_node: 2,
                cold_jobs_per_block: 1500, blocks: 4,
            },
        ]
    };

    let mut rows = Vec::new();
    for sc in &specs {
        // Fewer workers than sites: exactly the regime where the hot
        // shard serializes behind its static chunk without stealing.
        let threads = (sc.sites() as usize / 2).max(2);
        let cfg = StealConfig::new(threads);
        println!("\n--- {} ({} sites, hot x{}, {} jobs, {threads} \
                  threads) ---",
                 sc.name, sc.sites(), sc.hot_mul, sc.total_jobs());

        let (m_sq, d_sq, _recs_sq) =
            run_skew(sc, 7, &SkewEngine::SingleQueue, None);
        report_line("skew-single-q", &m_sq);
        let (m_par, d_par, _) =
            run_skew(sc, 7, &SkewEngine::Parallel(threads), None);
        assert_eq!(d_sq, d_par,
                   "chunked parallel replay diverged on {}", sc.name);
        report_line(&format!("skew-par[{threads}t]"), &m_par);
        let (m_steal, d_steal, recs_steal) =
            run_skew(sc, 7, &SkewEngine::Stealing(cfg), None);
        assert_eq!(d_sq, d_steal,
                   "stealing replay diverged on {}", sc.name);
        report_line(&format!("skew-steal[{threads}t]"), &m_steal);

        let vs_par = m_steal.events_per_sec
            / m_par.events_per_sec.max(1e-9);
        let vs_sq = m_steal.events_per_sec
            / m_sq.events_per_sec.max(1e-9);
        println!("  steal vs no-steal  {vs_par:>11.2}x events/sec   \
                  (vs single-queue {vs_sq:.2}x)");

        // Metrics memory story: in-memory per-shard recorders vs the
        // streaming spill path, which must merge byte-identically.
        let mem_bytes: usize =
            recs_steal.iter().map(Recorder::approx_bytes).sum();
        let merged_mem =
            Recorder::merge_shards(NodeNames::new(), &recs_steal);
        let dir = std::env::temp_dir()
            .join(format!("evhc_bench_spill_{}", sc.name));
        let _ = std::fs::remove_dir_all(&dir);
        let (m_spill, d_spill, recs_spill) =
            run_skew(sc, 7, &SkewEngine::Stealing(cfg), Some(dir.as_path()));
        assert_eq!(d_sq, d_spill,
                   "spill-mode stealing replay diverged on {}", sc.name);
        report_line("skew-steal-spill", &m_spill);
        let files: Vec<SpillFiles> = recs_spill
            .into_iter()
            .map(|mut r| {
                r.finish_spill().expect("spilling").expect("spill io")
            })
            .collect();
        let spill_bytes: u64 = files.iter().map(|f| f.bytes).sum();
        let merged_spill = Recorder::merge_spills(NodeNames::new(), &files)
            .expect("spill merge");
        let until = SimTime(sc.blocks as f64 * 900.0 + 3600.0);
        assert_eq!(merged_mem.fig10_usage(300.0, until).to_csv(),
                   merged_spill.fig10_usage(300.0, until).to_csv(),
                   "spill merge fig10 diverged on {}", sc.name);
        assert_eq!(merged_mem.fig11_states(300.0, until).to_csv(),
                   merged_spill.fig11_states(300.0, until).to_csv(),
                   "spill merge fig11 diverged on {}", sc.name);
        let merged_bytes = merged_spill.approx_bytes();
        let _ = std::fs::remove_dir_all(&dir);
        println!("  recorder bytes     {mem_bytes:>11} in-memory  \
                  {spill_bytes:>11} spilled  {merged_bytes:>11} merged");

        rows.push(Json::Object(vec![
            ("name".into(), Json::Str(sc.name.into())),
            ("sites".into(), Json::Num(sc.sites() as f64)),
            ("threads".into(), Json::Num(threads as f64)),
            ("hot_mul".into(), Json::Num(sc.hot_mul as f64)),
            ("jobs".into(), Json::Num(sc.total_jobs() as f64)),
            ("single_queue".into(), measured_json(&m_sq)),
            ("parallel".into(), measured_json(&m_par)),
            ("stealing".into(), measured_json(&m_steal)),
            ("stealing_spill".into(), measured_json(&m_spill)),
            ("speedup_steal_vs_parallel".into(), Json::Num(vs_par)),
            ("speedup_steal_vs_single_queue".into(), Json::Num(vs_sq)),
            ("recorder_bytes_in_memory".into(),
             Json::Num(mem_bytes as f64)),
            ("recorder_spill_file_bytes".into(),
             Json::Num(spill_bytes as f64)),
            ("recorder_bytes_merged".into(),
             Json::Num(merged_bytes as f64)),
        ]));
    }
    Json::Array(rows)
}

fn measured_json(m: &Measured) -> Json {
    Json::Object(vec![
        ("events".into(), Json::Num(m.events as f64)),
        ("wall_s".into(), Json::Num(m.wall_s)),
        ("events_per_sec".into(), Json::Num(m.events_per_sec)),
        ("ms_per_tick".into(), Json::Num(m.ms_per_tick)),
        ("completed".into(), Json::Num(m.completed as f64)),
    ])
}

fn report_line(label: &str, m: &Measured) {
    println!(
        "  {label:<18} {:>12.0} ev/s  {:>9.4} ms/tick  \
         ({} events, {:.2}s wall, {} jobs)",
        m.events_per_sec, m.ms_per_tick, m.events, m.wall_s, m.completed
    );
}

// ---------------------------------------------------------------------
// Broker: policy × scenario × multi-site elasticity runs
// ---------------------------------------------------------------------

/// Build a policy/scenario world: CESNET + AWS (the paper pair), an AWS
/// spot market from 3 sites up, opportunistic OpenNebula sites beyond —
/// the shared `RunConfig::paper_usecase_sites` ladder.
fn broker_cfg(policy: PolicyKind, scenario: &ScenarioPlan,
              n_sites: usize, scale: f64) -> RunConfig {
    let mut cfg = RunConfig::paper_usecase_sites(scale, 7, n_sites);
    cfg.inference_every = 0;
    cfg.policy = policy;
    cfg.scenario = scenario.clone();
    cfg
}

fn broker_run(policy: PolicyKind, scenario: &ScenarioPlan,
              n_sites: usize, scale: f64) -> RunReport {
    HybridCluster::new(broker_cfg(policy, scenario, n_sites, scale))
        .expect("broker world")
        .run()
        .expect("broker run")
}

/// Everything that must match bit-for-bit between two replays — the
/// shared contract type, so the bench and the property tests cannot
/// drift apart.
fn broker_digest(r: &RunReport) -> evhc::cluster::RunDigest {
    r.determinism_digest()
}

fn broker_section(quick: bool) -> Json {
    let scale = if quick { 0.05 } else { 0.2 };
    let t_wave = if quick { 300.0 } else { 600.0 };
    let policies: Vec<PolicyKind> = if quick {
        vec![PolicyKind::SlaRank, PolicyKind::CostMin,
             PolicyKind::SpotAware]
    } else {
        PolicyKind::ALL.to_vec()
    };
    let mut scenarios: Vec<(&str, ScenarioPlan)> = vec![
        ("spot-wave", ScenarioPlan::new()
            .spot_wave(0, t_wave, 0)
            .spot_wave(1, t_wave * 2.0, 0)),
        ("site-outage", ScenarioPlan::new()
            .site_outage(1, t_wave, t_wave * 6.0)),
    ];
    if !quick {
        scenarios.push(("price-spike", ScenarioPlan::new()
            .price_spike(1, 0.0, 1_000_000.0, 8.0)));
    }
    let site_counts: &[usize] = if quick { &[2, 4] } else { &[2, 4, 8] };

    let mut rows = Vec::new();
    for &(sname, ref plan) in &scenarios {
        for &policy in &policies {
            for &n in site_counts {
                let wall = Instant::now();
                let r = broker_run(policy, plan, n, scale);
                let wall_s = wall.elapsed().as_secs_f64();
                // Deterministic across runs: replay and compare.
                let r2 = broker_run(policy, plan, n, scale);
                assert_eq!(broker_digest(&r), broker_digest(&r2),
                           "broker run diverged: {} {} {n} sites",
                           policy.label(), sname);
                println!(
                    "  {:<11} {:<11} {n}s  {:>8.1}s makespan  \
                     ${:<8.4} {:>4} preempted {:>4} jobs recovered {:>4}",
                    policy.label(), sname, r.makespan.0,
                    r.total_cost_usd, r.preempted_vms, r.preempted_jobs,
                    r.preempt_recovered
                );
                rows.push(Json::Object(vec![
                    ("name".into(), Json::Str(format!(
                        "{}-{}-{}s", policy.label(), sname, n))),
                    ("policy".into(), Json::Str(policy.label().into())),
                    ("scenario".into(), Json::Str(sname.into())),
                    ("sites".into(), Json::Num(n as f64)),
                    ("jobs".into(), Json::Num(r.jobs_completed as f64)),
                    ("makespan_s".into(), Json::Num(r.makespan.0)),
                    ("cost_usd".into(), Json::Num(r.total_cost_usd)),
                    ("preempted_vms".into(),
                     Json::Num(r.preempted_vms as f64)),
                    ("preempted_jobs".into(),
                     Json::Num(r.preempted_jobs as f64)),
                    ("preempt_recovered".into(),
                     Json::Num(r.preempt_recovered as f64)),
                    ("events".into(), Json::Num(r.events as f64)),
                    ("wall_s".into(), Json::Num(wall_s)),
                    ("events_per_sec".into(),
                     Json::Num(r.events as f64 / wall_s.max(1e-9))),
                ]));
            }
        }
    }
    Json::Array(rows)
}

// ---------------------------------------------------------------------
// Chaos: WAN fault injection overhead on the paper use case
// ---------------------------------------------------------------------

fn chaos_run_cfg(scale: f64, n_sites: usize, engine: Engine,
                 faults: &WanFaultPlan) -> RunConfig {
    let mut cfg = RunConfig::paper_usecase_sites(scale, 7, n_sites);
    cfg.inference_every = 0;
    cfg.engine = engine;
    cfg.faults = faults.clone();
    cfg
}

/// Self-healing overhead under scripted WAN chaos: steady 1% / 5%
/// message loss on the remote sites and a mid-run 900 s partition,
/// each compared against a fault-free reference run (recovery
/// overhead = chaos makespan / clean makespan) and replayed on all
/// three engines with an in-bench digest-equality assert. These rows
/// are wall-clock sensitive, so `bench_compare` diffs them warn-only.
fn chaos_section(quick: bool) -> Json {
    let scale = if quick { 0.05 } else { 0.15 };
    let n_sites = 3;
    let variants: Vec<(&str, WanFaultPlan)> = vec![
        ("loss-1pct", WanFaultPlan::new(0xC4A0)
            .lossy(1, 0.0, 50_000.0, 0.01)
            .lossy(2, 0.0, 50_000.0, 0.01)),
        ("loss-5pct", WanFaultPlan::new(0xC4A1)
            .lossy(1, 0.0, 50_000.0, 0.05)
            .lossy(2, 0.0, 50_000.0, 0.05)),
        ("partition-900s", WanFaultPlan::new(0xC4A2)
            .partition(1, 1500.0, 900.0)),
    ];

    // Fault-free reference for the recovery-overhead ratio.
    let clean = HybridCluster::new(chaos_run_cfg(
            scale, n_sites, Engine::Serial, &WanFaultPlan::default()))
        .expect("chaos baseline world")
        .run()
        .expect("chaos baseline run");
    println!("  {:<15} {:>9.1}s makespan (fault-free reference)",
             "clean", clean.makespan.0);

    let mut rows = Vec::new();
    for (name, plan) in &variants {
        let wall = Instant::now();
        let r = HybridCluster::new(chaos_run_cfg(
                scale, n_sites, Engine::Serial, plan))
            .expect("chaos world")
            .run()
            .expect("chaos run");
        let wall_s = wall.elapsed().as_secs_f64();
        assert_eq!(r.jobs_completed, clean.jobs_completed,
                   "chaos run lost jobs: {name}");
        // Chaos must not break the cross-engine replay contract: the
        // fault streams are keyed by (site, seq), not by engine.
        for engine in [Engine::Sharded { threads: 0 },
                       Engine::Stealing { threads: 0 }] {
            let rp = HybridCluster::new(chaos_run_cfg(
                    scale, n_sites, engine, plan))
                .expect("chaos world")
                .run()
                .expect("chaos run");
            assert_eq!(rp.determinism_digest(), r.determinism_digest(),
                       "chaos replay diverged: {name} under {}",
                       engine.label());
        }
        let overhead = r.makespan.0 / clean.makespan.0.max(1e-9);
        let jobs_per_sec = r.jobs_completed as f64 / wall_s.max(1e-9);
        println!("  {name:<15} {:>9.1}s makespan ({overhead:.3}x clean)  \
                  {:>5} dropped {:>5} retx {:>2} quarantines  \
                  {jobs_per_sec:>8.0} jobs/s",
                 r.makespan.0, r.messages_dropped,
                 r.messages_retransmitted, r.quarantine_windows);
        rows.push(Json::Object(vec![
            ("name".into(), Json::Str((*name).into())),
            ("sites".into(), Json::Num(n_sites as f64)),
            ("jobs".into(), Json::Num(r.jobs_completed as f64)),
            ("makespan_s".into(), Json::Num(r.makespan.0)),
            ("makespan_clean_s".into(), Json::Num(clean.makespan.0)),
            ("recovery_overhead".into(), Json::Num(overhead)),
            ("completed_jobs_per_sec".into(), Json::Num(jobs_per_sec)),
            ("wall_s".into(), Json::Num(wall_s)),
            ("events".into(), Json::Num(r.events as f64)),
            ("messages_dropped".into(),
             Json::Num(r.messages_dropped as f64)),
            ("messages_duplicated".into(),
             Json::Num(r.messages_duplicated as f64)),
            ("messages_retransmitted".into(),
             Json::Num(r.messages_retransmitted as f64)),
            ("provision_retries".into(),
             Json::Num(r.provision_retries as f64)),
            ("quarantine_windows".into(),
             Json::Num(r.quarantine_windows as f64)),
            ("quarantine_secs".into(), Json::Num(r.quarantine_secs)),
            ("lease_requeued_jobs".into(),
             Json::Num(r.lease_requeued_jobs as f64)),
            ("lease_recovered_jobs".into(),
             Json::Num(r.lease_recovered_jobs as f64)),
        ]));
    }
    Json::Array(rows)
}

// ---------------------------------------------------------------------
// Chaos sweep: the recovery-overhead frontier
// ---------------------------------------------------------------------

/// How many grid points the sweep visits, bounded by
/// `EVHC_SWEEP_POINTS` (CI keeps the sweep small; unset full mode walks
/// the whole frontier).
fn sweep_points(quick: bool) -> usize {
    std::env::var("EVHC_SWEEP_POINTS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(if quick { 4 } else { 8 })
        .max(1)
}

/// One frontier row, shaped like the `chaos` rows plus the swept knobs
/// so `bench_compare` can diff both sections with the same code.
fn sweep_row(name: String, policy: &'static str, loss: f64,
             retry: &RetryPolicy, r: &RunReport, clean: &RunReport,
             wall_s: f64) -> Json {
    let overhead = r.makespan.0 / clean.makespan.0.max(1e-9);
    let jobs_per_sec = r.jobs_completed as f64 / wall_s.max(1e-9);
    Json::Object(vec![
        ("name".into(), Json::Str(name)),
        ("policy".into(), Json::Str(policy.into())),
        ("loss".into(), Json::Num(loss)),
        ("base_backoff_s".into(), Json::Num(retry.base_backoff_s)),
        ("failover_after".into(), Json::Num(retry.failover_after as f64)),
        ("quarantine_after".into(),
         Json::Num(retry.quarantine_after as f64)),
        ("sites".into(), Json::Num(r.site_health.len() as f64)),
        ("jobs".into(), Json::Num(r.jobs_completed as f64)),
        ("makespan_s".into(), Json::Num(r.makespan.0)),
        ("makespan_clean_s".into(), Json::Num(clean.makespan.0)),
        ("recovery_overhead".into(), Json::Num(overhead)),
        ("completed_jobs_per_sec".into(), Json::Num(jobs_per_sec)),
        ("wall_s".into(), Json::Num(wall_s)),
        ("events".into(), Json::Num(r.events as f64)),
        ("messages_dropped".into(), Json::Num(r.messages_dropped as f64)),
        ("messages_retransmitted".into(),
         Json::Num(r.messages_retransmitted as f64)),
        ("provision_retries".into(),
         Json::Num(r.provision_retries as f64)),
        ("quarantine_windows".into(),
         Json::Num(r.quarantine_windows as f64)),
        ("quarantine_secs".into(), Json::Num(r.quarantine_secs)),
        ("lease_requeued_jobs".into(),
         Json::Num(r.lease_requeued_jobs as f64)),
        ("lease_recovered_jobs".into(),
         Json::Num(r.lease_recovered_jobs as f64)),
    ])
}

/// The recovery-overhead frontier: sweep the self-healing
/// [`RetryPolicy`] knobs (backoff base, provisioning-failover
/// threshold, heartbeat-breaker threshold) × WAN loss severity on the
/// paper ladder and record where every point lands on the
/// recovery-overhead / completed-jobs-per-sec plane.
/// `EVHC_SWEEP_POINTS` bounds the grid walk (CI visits a prefix).
///
/// The section closes with the adaptive-placement headline pair: under
/// sustained severe loss at the SLA-preferred burst site,
/// [`PolicyKind::HealthAware`] must land at a strictly lower recovery
/// overhead than the static [`PolicyKind::SlaRank`] it extends —
/// asserted in-bench, alongside the usual cross-engine digest
/// equality. Like `chaos`, these rows are wall-clock sensitive and are
/// diffed warn-only by `bench_compare`.
fn chaos_sweep_section(quick: bool) -> Json {
    let scale = if quick { 0.05 } else { 0.1 };
    let n_sites = 3;
    let points = sweep_points(quick);

    // (name, base_backoff_s, failover_after, quarantine_after, loss) —
    // a fixed walk order so a bounded run always visits a stable
    // prefix and baseline rows keep their names.
    let grid: [(&str, f64, u32, u32, f64); 8] = [
        ("retry-default-loss5", 30.0, 2, 3, 0.05),
        ("fast-backoff-loss5", 10.0, 2, 3, 0.05),
        ("eager-failover-loss5", 30.0, 1, 2, 0.05),
        ("patient-breaker-loss5", 60.0, 3, 6, 0.05),
        ("retry-default-loss25", 30.0, 2, 3, 0.25),
        ("fast-backoff-loss25", 10.0, 2, 3, 0.25),
        ("eager-failover-loss25", 30.0, 1, 2, 0.25),
        ("patient-breaker-loss25", 60.0, 3, 6, 0.25),
    ];
    if points < grid.len() {
        println!("  (EVHC_SWEEP_POINTS: visiting {points} of {} grid \
                  points)", grid.len());
    }

    // One fault-free reference shared by every point: the swept knobs
    // only matter once faults fire, so the denominator is common.
    let clean = HybridCluster::new(chaos_run_cfg(
            scale, n_sites, Engine::Serial, &WanFaultPlan::default()))
        .expect("sweep baseline world")
        .run()
        .expect("sweep baseline run");
    println!("  {:<24} {:>9.1}s makespan (fault-free reference)",
             "clean", clean.makespan.0);

    let mut rows = Vec::new();
    for &(name, backoff, failover, breaker, loss)
        in grid.iter().take(points)
    {
        // Same stream seed per loss level, so points at one loss level
        // see identical drop streams and isolate the retry knobs.
        let plan = WanFaultPlan::new(0xC4B0)
            .lossy(1, 0.0, 50_000.0, loss)
            .lossy(2, 0.0, 50_000.0, loss);
        let build = |engine: Engine| {
            let mut cfg = chaos_run_cfg(scale, n_sites, engine, &plan);
            cfg.retry.base_backoff_s = backoff;
            cfg.retry.failover_after = failover;
            cfg.retry.quarantine_after = breaker;
            cfg
        };
        let wall = Instant::now();
        let r = HybridCluster::new(build(Engine::Serial))
            .expect("sweep world")
            .run()
            .expect("sweep run");
        let wall_s = wall.elapsed().as_secs_f64();
        assert_eq!(r.jobs_completed, clean.jobs_completed,
                   "sweep point lost jobs: {name}");
        let rp = HybridCluster::new(build(Engine::Sharded { threads: 0 }))
            .expect("sweep world")
            .run()
            .expect("sweep run");
        assert_eq!(rp.determinism_digest(), r.determinism_digest(),
                   "sweep replay diverged: {name} under sharded");
        let overhead = r.makespan.0 / clean.makespan.0.max(1e-9);
        println!("  {name:<24} {:>9.1}s makespan ({overhead:.3}x clean)  \
                  {:>5} dropped {:>5} retx {:>2} quarantines",
                 r.makespan.0, r.messages_dropped,
                 r.messages_retransmitted, r.quarantine_windows);
        let retry = RetryPolicy {
            base_backoff_s: backoff,
            failover_after: failover,
            quarantine_after: breaker,
            ..RetryPolicy::default()
        };
        rows.push(sweep_row(name.into(), PolicyKind::SlaRank.label(),
                            loss, &retry, &r, &clean, wall_s));
    }

    // Adaptive-placement headline: sustained severe loss at the
    // SLA-preferred burst site (AWS). The spot market gets a backup
    // SLA so de-ranking has an SLA-ranked site to steer to — without
    // one, no-SLA sites score +inf and no finite health demotion can
    // reach them. Identical configs either side, policy excepted.
    let severe = WanFaultPlan::new(0xC4B1).lossy(1, 0.0, 50_000.0, 0.35);
    let build_adaptive = |policy: PolicyKind, engine: Engine| {
        let mut cfg = chaos_run_cfg(scale, n_sites, engine, &severe);
        cfg.policy = policy;
        cfg.slas.push(Sla { site_name: "AWS-spot".into(), priority: 2,
                            max_instances: None });
        cfg
    };
    let mut overheads = Vec::new();
    for policy in [PolicyKind::SlaRank, PolicyKind::HealthAware] {
        let wall = Instant::now();
        let r = HybridCluster::new(build_adaptive(policy, Engine::Serial))
            .expect("adaptive world")
            .run()
            .expect("adaptive run");
        let wall_s = wall.elapsed().as_secs_f64();
        assert_eq!(r.jobs_completed, clean.jobs_completed,
                   "adaptive run lost jobs: {}", policy.label());
        for engine in [Engine::Sharded { threads: 0 },
                       Engine::Stealing { threads: 0 }] {
            let rp = HybridCluster::new(build_adaptive(policy, engine))
                .expect("adaptive world")
                .run()
                .expect("adaptive run");
            assert_eq!(rp.determinism_digest(), r.determinism_digest(),
                       "adaptive replay diverged: {} under {}",
                       policy.label(), engine.label());
        }
        let overhead = r.makespan.0 / clean.makespan.0.max(1e-9);
        let name = format!("adaptive-{}-loss35", policy.label());
        println!("  {name:<24} {:>9.1}s makespan ({overhead:.3}x clean)  \
                  site1 health floor {:.3}, de-ranked {}",
                 r.makespan.0, r.site_health_min[1],
                 match r.site_deranked_at[1] {
                     Some(t) => format!("at {t:.0}s"),
                     None => "never".into(),
                 });
        if policy == PolicyKind::HealthAware {
            assert!(r.site_deranked_at[1].is_some(),
                    "sustained 35% loss must de-rank the lossy site");
        }
        rows.push(sweep_row(name, policy.label(), 0.35,
                            &RetryPolicy::default(), &r, &clean, wall_s));
        overheads.push(overhead);
    }
    assert!(overheads[1] < overheads[0],
            "health-aware placement must beat static sla-rank under \
             sustained loss: {:.3}x vs {:.3}x clean",
            overheads[1], overheads[0]);
    println!("  health-aware wins the frontier: {:.3}x vs {:.3}x clean \
              recovery overhead", overheads[1], overheads[0]);
    Json::Array(rows)
}

// ---------------------------------------------------------------------
// Cluster: the real paper use case across the three replay engines
// ---------------------------------------------------------------------

/// A production-sized paper topology: `nodes` workers spread over the
/// `RunConfig::paper_usecase_sites` ladder, each site's quota carved to
/// hold its share, the full block-structured workload scaled to
/// `jobs_per_node` jobs per worker.
struct ClusterScale {
    name: &'static str,
    nodes: u32,
    sites: usize,
    jobs_per_node: u32,
}

impl ClusterScale {
    fn jobs(&self) -> u32 {
        self.nodes * self.jobs_per_node
    }
}

fn cluster_cfg(sc: &ClusterScale, engine: Engine,
               spill: Option<std::path::PathBuf>) -> RunConfig {
    let mut cfg = RunConfig::paper_usecase_sites(1.0, 7, sc.sites);
    cfg.inference_every = 0;
    cfg.engine = engine;
    cfg.metrics_spill_dir = spill;
    cfg.template.scalable.count = sc.nodes;
    cfg.template.scalable.min_instances = 0;
    cfg.template.scalable.max_instances = sc.nodes;
    // Carve each site's quota to roughly its share of the fleet (plus
    // slack for the FE and vRouters) so the workers genuinely spread
    // across every site shard.
    let share = sc.nodes / sc.sites as u32 + 4;
    let cpus = cfg.template.worker.num_cpus;
    for site in &mut cfg.sites {
        site.quota.max_vms = share as usize + 4;
        site.quota.max_vcpus = (share + 4) * cpus;
        site.quota.max_public_ips = 8;
    }
    // Fixed-spacing blocks: `Workload::paper` scales the block gaps
    // with the job count, which at bench scale would push later blocks
    // past the horizon.
    let total = sc.jobs();
    let per = total / 4;
    cfg.workload = evhc::workload::Workload {
        blocks: [0.0f64, 900.0, 1800.0, 2700.0]
            .iter()
            .zip([per, per, per, total - 3 * per])
            .map(|(&at, jobs)| evhc::workload::Block {
                at: SimTime(at),
                jobs,
            })
            .collect(),
        setup_secs: evhc::workload::SETUP_SECS_MEAN,
    };
    cfg
}

fn cluster_run(sc: &ClusterScale, engine: Engine,
               spill: Option<std::path::PathBuf>)
    -> (RunReport, Measured) {
    let wall = Instant::now();
    let report = HybridCluster::new(cluster_cfg(sc, engine, spill))
        .expect("cluster world")
        .run()
        .expect("cluster run");
    let wall_s = wall.elapsed().as_secs_f64();
    assert_eq!(report.jobs_completed, sc.jobs(),
               "cluster run must drain the workload ({})", sc.name);
    let m = Measured {
        events: report.events,
        wall_s,
        events_per_sec: report.events as f64 / wall_s.max(1e-9),
        ms_per_tick: 0.0,
        completed: report.jobs_completed,
    };
    (report, m)
}

/// [`cluster_run`] under [`DispatchMode::Partitioned`]: scheduling
/// inside the site shards, the control plane reduced to block routing
/// and spillover arbitration.
fn cluster_run_partitioned(sc: &ClusterScale, engine: Engine)
    -> (RunReport, Measured) {
    let wall = Instant::now();
    let mut cfg = cluster_cfg(sc, engine, None);
    cfg.dispatch = DispatchMode::Partitioned;
    let report = HybridCluster::new(cfg)
        .expect("cluster world")
        .run()
        .expect("cluster run");
    let wall_s = wall.elapsed().as_secs_f64();
    assert_eq!(report.jobs_completed, sc.jobs(),
               "partitioned cluster run must drain the workload ({})",
               sc.name);
    let m = Measured {
        events: report.events,
        wall_s,
        events_per_sec: report.events as f64 / wall_s.max(1e-9),
        ms_per_tick: 0.0,
        completed: report.jobs_completed,
    };
    (report, m)
}

fn cluster_section(quick: bool) -> Json {
    let scales: Vec<ClusterScale> = if quick {
        vec![ClusterScale { name: "paper-200n-4s", nodes: 200, sites: 4,
                            jobs_per_node: 8 }]
    } else {
        vec![
            ClusterScale { name: "paper-1k-4s", nodes: 1000, sites: 4,
                           jobs_per_node: 12 },
            ClusterScale { name: "paper-5k-6s", nodes: 5000, sites: 6,
                           jobs_per_node: 12 },
            ClusterScale { name: "paper-10k-8s", nodes: 10_000, sites: 8,
                           jobs_per_node: 10 },
        ]
    };

    let mut rows = Vec::new();
    for sc in &scales {
        println!("\n--- {} ({} nodes, {} sites, {} jobs) ---",
                 sc.name, sc.nodes, sc.sites, sc.jobs());
        let (r_serial, m_serial) = cluster_run(sc, Engine::Serial, None);
        report_line("serial", &m_serial);
        let (r_sharded, m_sharded) =
            cluster_run(sc, Engine::Sharded { threads: 0 }, None);
        assert_eq!(r_sharded.determinism_digest(), r_serial.determinism_digest(),
                   "sharded cluster replay diverged on {}", sc.name);
        report_line("sharded", &m_sharded);
        let (r_steal, m_steal) = cluster_run(
            sc, Engine::Stealing { threads: 0 }, None);
        assert_eq!(r_steal.determinism_digest(), r_serial.determinism_digest(),
                   "stealing cluster replay diverged on {}", sc.name);
        report_line("stealing", &m_steal);

        // Figures must be byte-identical across engines.
        let until = r_serial.makespan;
        let f10 = r_serial.recorder.fig10_usage(300.0, until).to_csv();
        let f11 = r_serial.recorder.fig11_states(300.0, until).to_csv();
        assert_eq!(r_steal.recorder.fig10_usage(300.0, until).to_csv(),
                   f10, "fig10 diverged across engines on {}", sc.name);
        assert_eq!(r_steal.recorder.fig11_states(300.0, until).to_csv(),
                   f11, "fig11 diverged across engines on {}", sc.name);

        // Spill mode under stealing: same digest, and the figures
        // rendered *straight from the spill streams* (no merged
        // recorder materialized) must reproduce the in-memory render.
        let dir = std::env::temp_dir()
            .join(format!("evhc_bench_cluster_{}", sc.name));
        let _ = std::fs::remove_dir_all(&dir);
        let (r_spill, m_spill) = cluster_run(
            sc, Engine::Stealing { threads: 0 }, Some(dir.clone()));
        assert_eq!(r_spill.determinism_digest(), r_serial.determinism_digest(),
                   "spill cluster replay diverged on {}", sc.name);
        report_line("stealing-spill", &m_spill);
        let spills: Vec<SpillFiles> = (0..=sc.sites)
            .map(|i| SpillFiles::locate(&dir, i as u32))
            .collect();
        assert_eq!(Recorder::fig10_from_spills(&spills, 300.0, until)
                       .expect("fig10 from spills")
                       .to_csv(),
                   f10, "streamed fig10 diverged on {}", sc.name);
        assert_eq!(Recorder::fig11_from_spills(&spills, 300.0, until)
                       .expect("fig11 from spills")
                       .to_csv(),
                   f11, "streamed fig11 diverged on {}", sc.name);
        let _ = std::fs::remove_dir_all(&dir);

        // Partitioned dispatch: scheduling inside the site shards, the
        // control plane reduced to routing + spill arbitration. The
        // three engines must replay byte-identically *within* the
        // mode; the two modes' timelines legitimately differ (block
        // routing, WAN report lag), so there is no cross-mode digest
        // compare — completion-set equivalence lives in
        // `tests/partitioned_dispatch.rs`.
        let (rp_serial, mp_serial) =
            cluster_run_partitioned(sc, Engine::Serial);
        report_line("part-serial", &mp_serial);
        let (rp_sharded, mp_sharded) =
            cluster_run_partitioned(sc, Engine::Sharded { threads: 0 });
        assert_eq!(rp_sharded.determinism_digest(),
                   rp_serial.determinism_digest(),
                   "partitioned sharded replay diverged on {}", sc.name);
        report_line("part-sharded", &mp_sharded);
        let (rp_steal, mp_steal) =
            cluster_run_partitioned(sc, Engine::Stealing { threads: 0 });
        assert_eq!(rp_steal.determinism_digest(),
                   rp_serial.determinism_digest(),
                   "partitioned stealing replay diverged on {}",
                   sc.name);
        report_line("part-stealing", &mp_steal);

        let sharded_speedup = m_sharded.events_per_sec
            / m_serial.events_per_sec.max(1e-9);
        let steal_speedup = m_steal.events_per_sec
            / m_serial.events_per_sec.max(1e-9);
        println!("  engine speedup     sharded {sharded_speedup:.2}x  \
                  stealing {steal_speedup:.2}x (vs serial)");
        let part_sharded_speedup = mp_sharded.events_per_sec
            / mp_serial.events_per_sec.max(1e-9);
        let part_steal_speedup = mp_steal.events_per_sec
            / mp_serial.events_per_sec.max(1e-9);
        println!("  partitioned        sharded {part_sharded_speedup:.2}x  \
                  stealing {part_steal_speedup:.2}x (vs part-serial)");

        rows.push(Json::Object(vec![
            ("name".into(), Json::Str(sc.name.into())),
            ("nodes".into(), Json::Num(sc.nodes as f64)),
            ("sites".into(), Json::Num(sc.sites as f64)),
            ("jobs".into(), Json::Num(sc.jobs() as f64)),
            ("serial".into(), measured_json(&m_serial)),
            ("sharded".into(), measured_json(&m_sharded)),
            ("stealing".into(), measured_json(&m_steal)),
            ("stealing_spill".into(), measured_json(&m_spill)),
            ("partitioned_serial".into(), measured_json(&mp_serial)),
            ("partitioned_sharded".into(), measured_json(&mp_sharded)),
            ("partitioned_stealing".into(), measured_json(&mp_steal)),
            ("speedup_sharded_vs_serial".into(),
             Json::Num(sharded_speedup)),
            ("speedup_stealing_vs_serial".into(),
             Json::Num(steal_speedup)),
            ("speedup_partitioned_sharded_vs_serial".into(),
             Json::Num(part_sharded_speedup)),
            ("speedup_partitioned_stealing_vs_serial".into(),
             Json::Num(part_steal_speedup)),
        ]));
    }
    Json::Array(rows)
}

// ---------------------------------------------------------------------
// Trace: streaming multi-million-job replay in bounded memory
// ---------------------------------------------------------------------

/// The trace-bench topology: the paper ladder with a carved 200-node
/// fleet (the quota shaping of [`cluster_cfg`]) but no workload
/// override — arrivals come from the streaming source instead.
fn trace_cluster_cfg(nodes: u32, sites: usize, engine: Engine)
    -> RunConfig {
    let mut cfg = RunConfig::paper_usecase_sites(1.0, 7, sites);
    cfg.inference_every = 0;
    cfg.engine = engine;
    cfg.template.scalable.count = nodes;
    cfg.template.scalable.min_instances = 0;
    cfg.template.scalable.max_instances = nodes;
    let share = nodes / sites as u32 + 4;
    let cpus = cfg.template.worker.num_cpus;
    for site in &mut cfg.sites {
        site.quota.max_vms = share as usize + 4;
        site.quota.max_vcpus = (share + 4) * cpus;
        site.quota.max_public_ips = 8;
    }
    cfg
}

/// Mean arrival rate for the generated trace, jobs per simulated
/// second — ~0.9× the 200-node fleet's drain rate, so the backlog (and
/// with it broker pressure and RSS) stays bounded while CLUES still
/// breathes with the bursts.
const TRACE_RATE: f64 = 18.0;

fn trace_profile() -> ArrivalProfile {
    ArrivalProfile {
        base_rate: TRACE_RATE,
        diurnal_amplitude: 0.2,
        diurnal_period_s: 86_400.0,
        burst_prob: 0.02,
        burst_multiplier: 2.0,
        window_s: 60.0,
    }
}

fn trace_engine_json(jobs_per_sec: f64, wall_s: f64, events: u64,
                     rss_mb: f64) -> Json {
    Json::Object(vec![
        ("jobs_per_sec".into(), Json::Num(jobs_per_sec)),
        ("wall_s".into(), Json::Num(wall_s)),
        ("events".into(), Json::Num(events as f64)),
        ("rss_mb".into(), Json::Num(rss_mb)),
    ])
}

/// Streamed replay throughput: a generated burst/diurnal trace
/// (`EVHC_TRACE_JOBS` jobs; 20k quick, 1M full — point it at 10M for
/// the long-haul run) streamed through a bounded ingest watermark and
/// spill-mode recorders on all three engines. Asserts, in-bench:
/// cross-engine digest equality, 100% completion, the deterministic
/// frontend-memory bound (`peak_buffered_jobs` ≤ watermark + one
/// block), and `SynthSource ≡ Workload` digest identity. Jobs/sec is
/// the gated metric; RSS (via `util::rss`, warn-only) records the
/// constant-memory story.
fn trace_section(quick: bool) -> Json {
    let jobs: u64 = std::env::var("EVHC_TRACE_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick { 20_000 } else { 1_000_000 });
    let (nodes, sites) = (200u32, 4usize);
    let watermark: u32 = if quick { 5_000 } else { 50_000 };
    // One block is one arrival window; bursts and the sampling jitter
    // cap the worst case (rate × window × diurnal × burst × jitter).
    let max_block = (TRACE_RATE * 60.0 * 1.2 * 2.0 * 1.4) as u64;
    println!("\n--- stream-{jobs}j ({nodes} nodes, {sites} sites, \
              watermark {watermark} jobs) ---");

    let mk = |engine: Engine, spill: Option<std::path::PathBuf>| {
        let mut cfg = trace_cluster_cfg(nodes, sites, engine);
        cfg.source = Some(Box::new(
            ArrivalGen::new(7, jobs, trace_profile())
                .expect("trace profile")));
        cfg.ingest_watermark_jobs = watermark;
        cfg.metrics_spill_dir = spill;
        // The arrival span scales with the trace, so the safety stop
        // must too (1.5× span + drain slack).
        cfg.horizon = SimTime(jobs as f64 / TRACE_RATE * 1.5 + 30_000.0);
        cfg
    };

    let mut engines_json = Vec::new();
    let mut ref_digest = None;
    let mut peak_buffered = 0u64;
    let mut events = 0u64;
    for engine in [Engine::Serial, Engine::Sharded { threads: 0 },
                   Engine::Stealing { threads: 0 }] {
        let dir = std::env::temp_dir()
            .join(format!("evhc_bench_trace_{}", engine.label()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).expect("trace spill dir");
        let wall = Instant::now();
        let r = HybridCluster::new(mk(engine, Some(dir.clone())))
            .expect("trace world")
            .run()
            .expect("trace run");
        let wall_s = wall.elapsed().as_secs_f64();
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(r.jobs_completed as u64, jobs,
                   "streamed trace must drain every job ({})",
                   engine.label());
        match &ref_digest {
            None => ref_digest = Some(r.determinism_digest()),
            Some(d) => assert_eq!(&r.determinism_digest(), d,
                "streamed replay diverged on {}", engine.label()),
        }
        assert!(r.peak_buffered_jobs <= watermark as u64 + max_block,
                "frontend peak {} exceeds watermark {watermark} + one \
                 block {max_block}", r.peak_buffered_jobs);
        if jobs > watermark as u64 + max_block {
            assert!(r.peak_buffered_jobs < jobs,
                    "a bounded feed must never hold the whole trace");
        }
        peak_buffered = r.peak_buffered_jobs;
        events = r.events;
        let jobs_per_sec = jobs as f64 / wall_s.max(1e-9);
        let rss_mb = evhc::util::rss::current_rss_kb()
            .map(|kb| kb as f64 / 1024.0)
            .unwrap_or(0.0);
        println!("  {:<18} {jobs_per_sec:>12.0} jobs/s  \
                  ({} events, {wall_s:.2}s wall, rss {rss_mb:.0} MB)",
                 engine.label(), r.events);
        engines_json.push((engine.label().to_string(),
                           trace_engine_json(jobs_per_sec, wall_s,
                                             r.events, rss_mb)));
    }
    println!("  frontend peak      {peak_buffered} buffered jobs \
              (bound: watermark {watermark} + block <= {max_block})");

    // SynthSource ≡ Workload: a four-block materialized workload of
    // the same shape replays digest-identically whether it streams
    // through the implicit default wrapper or an explicitly
    // constructed SynthSource. Capped — this compare is about the
    // submission path, not throughput.
    let synth_jobs = jobs.min(100_000) as u32;
    let mk_synth = |explicit: bool| {
        let mut cfg = trace_cluster_cfg(nodes, sites, Engine::Serial);
        let per = synth_jobs / 4;
        cfg.workload = evhc::workload::Workload {
            blocks: [0.0f64, 900.0, 1800.0, 2700.0]
                .iter()
                .zip([per, per, per, synth_jobs - 3 * per])
                .map(|(&at, jobs)| evhc::workload::Block {
                    at: SimTime(at),
                    jobs,
                })
                .collect(),
            setup_secs: evhc::workload::SETUP_SECS_MEAN,
        };
        if explicit {
            cfg.source = Some(Box::new(
                SynthSource::new(cfg.workload.clone())));
        }
        cfg
    };
    let implicit = HybridCluster::new(mk_synth(false))
        .expect("synth world").run().expect("synth run");
    let explicit = HybridCluster::new(mk_synth(true))
        .expect("synth world").run().expect("synth run");
    assert_eq!(explicit.determinism_digest(),
               implicit.determinism_digest(),
               "SynthSource diverged from the materialized Workload");
    assert_eq!(implicit.jobs_completed, synth_jobs);
    println!("  synth == workload  digest-identical at {synth_jobs} \
              jobs");

    let mut fields = vec![
        ("name".into(), Json::Str(format!("stream-{jobs}j"))),
        ("jobs".into(), Json::Num(jobs as f64)),
        ("nodes".into(), Json::Num(nodes as f64)),
        ("sites".into(), Json::Num(sites as f64)),
        ("watermark_jobs".into(), Json::Num(watermark as f64)),
        ("events".into(), Json::Num(events as f64)),
        ("peak_buffered_jobs".into(),
         Json::Num(peak_buffered as f64)),
    ];
    for (label, j) in engines_json {
        fields.push((label, j));
    }
    Json::Array(vec![Json::Object(fields)])
}

// ---------------------------------------------------------------------
// Engine profiler + tracing overhead (the paper use case)
// ---------------------------------------------------------------------

fn profile_json(p: &EngineProfile) -> Json {
    Json::Object(vec![
        ("windows".into(), Json::Num(p.windows as f64)),
        ("serial_steps".into(), Json::Num(p.serial_steps as f64)),
        ("barrier_events".into(), Json::Num(p.barrier_events as f64)),
        ("barrier_wall_s".into(), Json::Num(p.barrier_wall_s)),
        ("window_wall_s".into(), Json::Num(p.window_wall_s)),
        ("busiest_shard_wall_s".into(),
         Json::Num(p.busiest_shard_wall_s)),
        ("worker_wall_s".into(), Json::Num(p.worker_wall_s)),
        ("chains_executed".into(), Json::Num(p.chains_executed as f64)),
        ("injector_wait_s".into(), Json::Num(p.injector_wait_s)),
        ("workers".into(), Json::Num(p.workers as f64)),
        ("barrier_fraction".into(), Json::Num(p.barrier_fraction())),
        ("parallel_efficiency".into(),
         Json::Num(p.parallel_efficiency())),
    ])
}

/// One paper-use-case run with an optional observability payload.
fn profiled_run(sc: &ClusterScale, engine: Engine, obs: bool)
    -> (RunReport, Measured) {
    let mut cfg = cluster_cfg(sc, engine, None);
    if obs {
        cfg.obs = ObsConfig::enabled();
    }
    let wall = Instant::now();
    let report = HybridCluster::new(cfg)
        .expect("profile world")
        .run()
        .expect("profile run");
    let wall_s = wall.elapsed().as_secs_f64();
    assert_eq!(report.jobs_completed, sc.jobs(),
               "profiled run must drain the workload ({})", sc.name);
    let m = Measured {
        events: report.events,
        wall_s,
        events_per_sec: report.events as f64 / wall_s.max(1e-9),
        ms_per_tick: 0.0,
        completed: report.jobs_completed,
    };
    (report, m)
}

/// The engine-profiler section: wall-time attribution for the parallel
/// engines (shard windows vs the control barrier vs injector waiting)
/// and the tracing-overhead ratio on the serial engine — with the
/// observability contract asserted in-bench (digest unchanged, Chrome
/// trace JSON parses, streams non-empty).
fn perf_profile_section(quick: bool) -> Json {
    let sc = if quick {
        ClusterScale { name: "paper-200n-4s", nodes: 200, sites: 4,
                       jobs_per_node: 8 }
    } else {
        ClusterScale { name: "paper-1k-4s", nodes: 1000, sites: 4,
                       jobs_per_node: 12 }
    };
    println!("\n--- {} ({} nodes, {} sites, {} jobs) ---",
             sc.name, sc.nodes, sc.sites, sc.jobs());

    let mut fields = vec![
        ("name".into(), Json::Str(sc.name.into())),
        ("nodes".into(), Json::Num(sc.nodes as f64)),
        ("sites".into(), Json::Num(sc.sites as f64)),
        ("jobs".into(), Json::Num(sc.jobs() as f64)),
    ];

    for engine in [Engine::Sharded { threads: 0 },
                   Engine::Stealing { threads: 0 }] {
        let (r, m) = profiled_run(&sc, engine, false);
        let p = r.profile
            .expect("parallel engines must carry a profile");
        assert!(p.windows > 0, "{} profile saw no windows",
                engine.label());
        println!(
            "  {:<14} {:>9.0} ev/s  windows={} window={:.0}ms \
             busiest-shard={:.0}ms barrier={:.0}ms ({:.0}%) \
             injector-wait={:.0}ms chains={} par-eff={:.2}",
            engine.label(),
            m.events_per_sec,
            p.windows,
            p.window_wall_s * 1e3,
            p.busiest_shard_wall_s * 1e3,
            p.barrier_wall_s * 1e3,
            p.barrier_fraction() * 100.0,
            p.injector_wait_s * 1e3,
            p.chains_executed,
            p.parallel_efficiency()
        );
        fields.push((engine.label().into(), Json::Object(vec![
            ("measured".into(), measured_json(&m)),
            ("profile".into(), profile_json(&p)),
        ])));
    }

    // Tracing overhead on the serial engine: the observability
    // contract, asserted where the overhead is measured.
    let (r_off, m_off) = profiled_run(&sc, Engine::Serial, false);
    let (r_on, m_on) = profiled_run(&sc, Engine::Serial, true);
    assert_eq!(r_on.determinism_digest(), r_off.determinism_digest(),
               "tracing must be digest-neutral");
    assert!(r_off.trace.is_none() && r_off.profile.is_none(),
            "an untraced serial run must carry no obs payload");
    let trace = r_on.trace.as_ref().expect("traced run carries a trace");
    let metrics = r_on.metrics.as_ref().expect("traced run has metrics");
    assert!(!trace.is_empty() && !metrics.is_empty(),
            "observability streams must not be empty");
    evhc::api::json::parse(&trace.to_chrome_json())
        .expect("chrome trace JSON must parse");
    let ratio = m_on.events_per_sec / m_off.events_per_sec.max(1e-9);
    println!(
        "  tracing        {:>9.0} -> {:.0} ev/s (x{ratio:.2}) — {} \
         trace events, {} metric samples",
        m_off.events_per_sec, m_on.events_per_sec, trace.len(),
        metrics.len()
    );
    fields.push(("tracing".into(), Json::Object(vec![
        ("events_per_sec_off".into(), Json::Num(m_off.events_per_sec)),
        ("events_per_sec_on".into(), Json::Num(m_on.events_per_sec)),
        ("ratio_on_vs_off".into(), Json::Num(ratio)),
        ("trace_events".into(), Json::Num(trace.len() as f64)),
        ("metric_samples".into(), Json::Num(metrics.len() as f64)),
    ])));

    Json::Object(fields)
}

fn main() {
    let quick = std::env::var("EVHC_SCALE_BENCH_QUICK").is_ok();

    // Sweep-only mode (`./ci.sh chaos-sweep`): just the
    // recovery-overhead frontier with its in-bench asserts, as a
    // smoke stage — BENCH_scale.json is left untouched so a partial
    // run never clobbers a full trajectory.
    if std::env::var("EVHC_SWEEP_ONLY").is_ok() {
        section("SCALE: recovery-overhead frontier (chaos sweep)");
        let _ = chaos_sweep_section(quick);
        println!("\nsweep-only mode: BENCH_scale.json left untouched");
        return;
    }

    let scenarios: Vec<Scenario> = if quick {
        vec![
            Scenario { name: "1k-nodes-20k-jobs", nodes: 1000, sites: 2,
                       jobs: 20_000, slots_per_node: 2, with_naive: true },
        ]
    } else {
        vec![
            Scenario { name: "1k-nodes-100k-jobs", nodes: 1000, sites: 2,
                       jobs: 100_000, slots_per_node: 2,
                       with_naive: true },
            Scenario { name: "5k-nodes-200k-jobs", nodes: 5000, sites: 4,
                       jobs: 200_000, slots_per_node: 2,
                       with_naive: true },
            Scenario { name: "10k-nodes-1M-jobs", nodes: 10_000, sites: 8,
                       jobs: 1_000_000, slots_per_node: 4,
                       with_naive: false },
        ]
    };

    section(&format!(
        "SCALE: scheduling hot path ({} mode)",
        if quick { "quick" } else { "full" }
    ));

    let mut rows = Vec::new();
    for sc in &scenarios {
        println!("\n--- {} ({} sites, {} slots/node) ---",
                 sc.name, sc.sites, sc.slots_per_node);
        let mut indexed_core = BatchCore::new(Placement::PackFirstFit);
        let indexed = run_scenario(&mut indexed_core, sc, 7);
        assert_eq!(indexed.completed, sc.jobs,
                   "indexed run must drain the workload");
        report_line("indexed", &indexed);

        let naive = if sc.with_naive {
            let mut naive_core = BatchCore::new_naive(Placement::PackFirstFit);
            let m = run_scenario(&mut naive_core, sc, 7);
            assert_eq!(m.completed, sc.jobs,
                       "naive run must drain the workload");
            report_line("naive-reference", &m);
            Some(m)
        } else {
            println!("  naive-reference    skipped (O(jobs x nodes) \
                      at this size)");
            None
        };

        let speedup = naive
            .map(|n| indexed.events_per_sec / n.events_per_sec.max(1e-9));
        if let Some(s) = speedup {
            println!("  speedup            {s:>11.1}x events/sec \
                      (indexed vs naive)");
        }

        // Sharded engine: the same workload split into per-site shards,
        // replayed through the single-queue (serial merge) engine and
        // the parallel windowed engine. Both must agree exactly.
        let threads = default_threads(sc.sites as usize);
        let (shard_single, d_single) =
            run_sharded_scenario(sc, 7, false, 1);
        let (shard_parallel, d_parallel) =
            run_sharded_scenario(sc, 7, true, threads);
        assert_eq!(d_single, d_parallel,
                   "parallel sharded replay diverged from single-queue");
        report_line("shard-single-q", &shard_single);
        report_line(&format!("shard-par[{threads}t]"), &shard_parallel);
        let shard_speedup = shard_parallel.events_per_sec
            / shard_single.events_per_sec.max(1e-9);
        println!("  sharded speedup    {shard_speedup:>11.1}x events/sec \
                  (parallel vs single-queue)");

        let mut fields = vec![
            ("name".into(), Json::Str(sc.name.into())),
            ("nodes".into(), Json::Num(sc.nodes as f64)),
            ("sites".into(), Json::Num(sc.sites as f64)),
            ("jobs".into(), Json::Num(sc.jobs as f64)),
            ("slots_per_node".into(),
             Json::Num(sc.slots_per_node as f64)),
            ("indexed".into(), measured_json(&indexed)),
        ];
        if let Some(n) = &naive {
            fields.push(("naive".into(), measured_json(n)));
        }
        if let Some(s) = speedup {
            fields.push(("speedup_events_per_sec".into(), Json::Num(s)));
        }
        fields.push(("sharded".into(), Json::Object(vec![
            ("single_queue".into(), measured_json(&shard_single)),
            ("parallel".into(), measured_json(&shard_parallel)),
            ("threads".into(), Json::Num(threads as f64)),
            ("speedup_events_per_sec".into(), Json::Num(shard_speedup)),
        ])));
        rows.push(Json::Object(fields));
    }

    // Spread policy spot-check so both index structures stay honest.
    section("SCALE: SpreadMostFree spot-check");
    // Distinct names per mode so bench_compare never diffs a 10k-job
    // quick run against a 50k-job full baseline row.
    let sc = Scenario {
        name: if quick { "spread-2k-10k" } else { "spread-2k-50k" },
        nodes: 2000,
        sites: 4,
        jobs: if quick { 10_000 } else { 50_000 },
        slots_per_node: 2,
        with_naive: true,
    };
    let mut spread_core = BatchCore::new(Placement::SpreadMostFree);
    let spread = run_scenario(&mut spread_core, &sc, 11);
    report_line("indexed-spread", &spread);
    let mut spread_naive_core = BatchCore::new_naive(Placement::SpreadMostFree);
    let spread_naive = run_scenario(&mut spread_naive_core, &sc, 11);
    report_line("naive-spread", &spread_naive);
    rows.push(Json::Object(vec![
        ("name".into(), Json::Str(sc.name.into())),
        ("nodes".into(), Json::Num(sc.nodes as f64)),
        ("sites".into(), Json::Num(sc.sites as f64)),
        ("jobs".into(), Json::Num(sc.jobs as f64)),
        ("slots_per_node".into(), Json::Num(sc.slots_per_node as f64)),
        ("indexed".into(), measured_json(&spread)),
        ("naive".into(), measured_json(&spread_naive)),
        ("speedup_events_per_sec".into(),
         Json::Num(spread.events_per_sec
                   / spread_naive.events_per_sec.max(1e-9))),
    ]));

    // Work-stealing on skewed worlds + streaming per-shard metrics,
    // with digest/figure equality asserts across engines and recording
    // paths.
    section("SCALE: work-stealing x skew x metrics spill");
    let stealing_rows = stealing_section(quick);

    // The real paper use case across the three replay engines, with
    // cross-engine digest + figure equality asserts and the
    // straight-from-spill figure render byte-compared in place.
    section("SCALE: paper use case x engines");
    let cluster_rows = cluster_section(quick);

    // Streaming trace frontend: a generated multi-(hundred-)thousand
    // job arrival process replayed in bounded frontend memory, with
    // cross-engine digest, completion, memory-bound and
    // SynthSource ≡ Workload asserts in-bench.
    section("SCALE: streaming trace replay");
    let trace_rows = trace_section(quick);

    // Broker: policy × scenario × multi-site elasticity runs, each
    // replayed twice with an in-bench determinism assert.
    section("SCALE: broker policy x scenario");
    let broker_rows = broker_section(quick);

    // Chaos: WAN fault injection overhead, cross-engine asserted.
    section("SCALE: wan chaos x self-healing");
    let chaos_rows = chaos_section(quick);

    // Chaos sweep: the recovery-overhead frontier over the RetryPolicy
    // knobs × loss severity, closing with the health-aware vs sla-rank
    // adaptive-placement headline assert.
    section("SCALE: recovery-overhead frontier (chaos sweep)");
    let chaos_sweep_rows = chaos_sweep_section(quick);

    // Engine profiler + tracing overhead, with the observability
    // contract asserted in-bench.
    section("SCALE: engine profiler x tracing overhead");
    let perf_profile_rows = perf_profile_section(quick);

    let doc = Json::Object(vec![
        ("bench".into(), Json::Str("scale".into())),
        ("quick".into(), Json::Bool(quick)),
        ("scenarios".into(), Json::Array(rows)),
        ("stealing".into(), stealing_rows),
        ("cluster".into(), cluster_rows),
        ("trace".into(), trace_rows),
        ("broker".into(), broker_rows),
        ("chaos".into(), chaos_rows),
        ("chaos_sweep".into(), chaos_sweep_rows),
        ("perf_profile".into(), perf_profile_rows),
    ]);
    std::fs::write("BENCH_scale.json", doc.render() + "\n")
        .expect("write BENCH_scale.json");
    println!("\nwrote BENCH_scale.json");
}
