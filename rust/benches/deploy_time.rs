//! T-DEPLOY + X-PAR — node deployment latency breakdown and the
//! serialized-vs-parallel orchestrator ablation (the paper's future-work
//! "parallel provisioning of nodes ... will reduce the deployment time").

use evhc::cluster::{HybridCluster, RunConfig, RunReport};
use evhc::im::{ctx_plan, ctx_total_secs, NodeRole};
use evhc::tosca::LrmsKind;
use evhc::util::bench::section;
use evhc::util::csv::Table;
use evhc::util::prng::Prng;
use evhc::util::stats::{mean, Summary};

fn run(serialized: bool) -> RunReport {
    let mut cfg = RunConfig::paper_usecase(0.5, 42);
    cfg.serialized_orchestrator = serialized;
    HybridCluster::new(cfg).unwrap().run().unwrap()
}

fn main() {
    section("T-DEPLOY: contextualization breakdown per role");
    let mut rng = Prng::new(7);
    let mut t = Table::new(vec!["role", "stage", "median_s"]);
    for (role, label) in [(NodeRole::FrontEnd, "front-end"),
                          (NodeRole::WorkerNode, "worker"),
                          (NodeRole::SiteVRouter, "vrouter")] {
        let plan = ctx_plan(role, LrmsKind::Slurm, &mut rng);
        for s in &plan {
            t.push(vec![label.to_string(), s.name.to_string(),
                        format!("{:.0}", s.secs)]);
        }
        println!("{label}: {:.1} min total ctx", ctx_total_secs(&plan)
                 / 60.0);
    }
    let _ = std::fs::create_dir_all("results");
    t.write("results/deploy_breakdown.csv").unwrap();

    section("worker deploy latency distribution (serialized, paper mode)");
    let ser = run(true);
    let ser_deploys: Vec<f64> = ser.deploy_times.iter()
        .filter(|(n, _, _)| n.starts_with("vnode-"))
        .map(|(_, r, j)| (j.0 - r.0) / 60.0)
        .collect();
    println!("  per-node deploy minutes: {}",
             Summary::of(&ser_deploys));
    println!("  (paper: ~19-20 minutes per AWS node)");

    section("X-PAR: serialized vs parallel orchestrator (ablation)");
    let par = run(false);
    let time_to_full = |r: &RunReport| -> f64 {
        r.deploy_times.iter()
            .filter(|(n, _, _)| n.starts_with("vnode-"))
            .map(|(_, _, j)| j.0)
            .fold(0.0f64, f64::max)
    };
    let ser_full = time_to_full(&ser) / 60.0;
    let par_full = time_to_full(&par) / 60.0;
    let mut ab = Table::new(vec!["mode", "last_worker_join_min",
                                 "makespan", "cost_usd"]);
    ab.push(vec!["serialized (paper)".into(), format!("{ser_full:.1}"),
                 ser.makespan.hms(),
                 format!("{:.2}", ser.total_cost_usd)]);
    ab.push(vec!["parallel (future work)".into(), format!("{par_full:.1}"),
                 par.makespan.hms(),
                 format!("{:.2}", par.total_cost_usd)]);
    print!("{}", ab.to_text());
    ab.write("results/deploy_ablation.csv").unwrap();

    // Shape: parallel provisioning reaches full capacity much earlier.
    assert!(par_full < ser_full,
            "parallel must reach capacity sooner ({par_full} !< {ser_full})");
    assert!(mean(&ser_deploys) > 10.0 && mean(&ser_deploys) < 30.0,
            "deploy latency out of the paper's band");
    println!("\nwrote results/deploy_breakdown.csv, \
              results/deploy_ablation.csv");
}
