//! FIG10 — cluster usage evolution (paper Figure 10).
//!
//! Runs the full §4 use case and regenerates the per-node busy-interval
//! series the paper plots, plus the headline observations: CESNET nodes
//! work from the start, AWS nodes join ~19–20 min apart (serialized
//! orchestrator), and every node is exercised.

use evhc::cloudsim::{InjectionPlan, TransientDown};
use evhc::cluster::{HybridCluster, RunConfig};
use evhc::sim::SimTime;
use evhc::util::bench::section;
use evhc::util::stats::mean;

fn main() {
    section("FIG10: cluster usage evolution (full-scale use case)");
    let mut cfg = RunConfig::paper_usecase(1.0, 42);
    cfg.injections = InjectionPlan {
        transient_downs: vec![TransientDown {
            node_name: "vnode-5".into(),
            start: SimTime(4800.0),
            duration_secs: 300.0,
        }],
    };
    let wall = std::time::Instant::now();
    let report = HybridCluster::new(cfg).unwrap().run().unwrap();
    println!("simulated {} ({} jobs) in {:.2}s wall",
             report.makespan, report.jobs_completed,
             wall.elapsed().as_secs_f64());

    let _ = std::fs::create_dir_all("results");
    let fig10 = report.recorder.fig10_usage(120.0, report.makespan);
    fig10.write("results/fig10_usage.csv").unwrap();
    println!("wrote results/fig10_usage.csv ({} rows x 2-min buckets)",
             fig10.len());

    section("per-node busy time (Fig. 10 integrals)");
    for r in &report.per_vm {
        if r.busy_hours > 0.0 {
            println!("  {:<12} {:<12} busy {:>5.2} h over {:>5.2} h alive",
                     r.name, r.site, r.busy_hours, r.hours);
        }
    }

    section("headline shape checks");
    // AWS nodes joined in a serialized staircase.
    let mut aws_joins: Vec<f64> = report
        .deploy_times
        .iter()
        .filter(|(n, _, _)| n.starts_with("vnode-"))
        .filter(|(_, req, _)| req.0 > 0.0)
        .map(|(_, _, j)| j.0)
        .collect();
    aws_joins.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let gaps: Vec<f64> = aws_joins.windows(2).map(|w| (w[1] - w[0]) / 60.0)
        .collect();
    println!("  node join staircase gaps (min): {:?}",
             gaps.iter().map(|g| format!("{g:.0}")).collect::<Vec<_>>());
    let deploy_mins: Vec<f64> = report
        .deploy_times
        .iter()
        .filter(|(n, _, _)| n.starts_with("vnode-"))
        .map(|(_, r, j)| (j.0 - r.0) / 60.0)
        .collect();
    println!("  mean worker deploy: {:.1} min (paper ~19-20 min)",
             mean(&deploy_mins));
    assert!(report.jobs_completed == 3676);
}
