//! T-COST + T-CF — §4.2 cost & utilization accounting and the
//! cloud-bursting counterfactual.
//!
//! Paper numbers: test lasted 5 h 40 m; ~20 CPU-hours total; AWS WNs
//! executed jobs for 9 h 42 m; 66% of AWS paid time was effective; total
//! AWS cost $0.75 (≈15 WN CPU-hours + 6 h of vRouter); without AWS the
//! workload would have taken ~4 extra hours on the two CESNET nodes.

use evhc::cloudsim::{InjectionPlan, TransientDown};
use evhc::cluster::{HybridCluster, RunConfig, RunReport};
use evhc::im::NodeRole;
use evhc::sim::SimTime;
use evhc::util::bench::section;
use evhc::util::csv::Table;

fn run(hybrid: bool) -> RunReport {
    let mut cfg = RunConfig::paper_usecase(1.0, 42);
    cfg.template.hybrid = hybrid;
    if hybrid {
        cfg.injections = InjectionPlan {
            transient_downs: vec![TransientDown {
                node_name: "vnode-5".into(),
                start: SimTime(4800.0),
                duration_secs: 300.0,
            }],
        };
    }
    HybridCluster::new(cfg).unwrap().run().unwrap()
}

fn main() {
    section("T-COST: §4.2 cost & utilization (hybrid run)");
    let hybrid = run(true);

    let mut t = Table::new(vec!["vm", "site", "role", "hours", "busy_h",
                                "cost_usd"]);
    for r in &hybrid.per_vm {
        t.push(vec![r.name.clone(), r.site.clone(),
                    format!("{:?}", r.role), format!("{:.2}", r.hours),
                    format!("{:.2}", r.busy_hours),
                    format!("{:.3}", r.cost_usd)]);
    }
    print!("{}", t.to_text());
    let _ = std::fs::create_dir_all("results");
    t.write("results/cost_table.csv").unwrap();

    let aws_wn: Vec<_> = hybrid.per_vm.iter()
        .filter(|r| r.site == "AWS" && r.role == NodeRole::WorkerNode)
        .collect();
    let aws_busy: f64 = aws_wn.iter().map(|r| r.busy_hours).sum();
    let aws_paid: f64 = aws_wn.iter().map(|r| r.hours).sum();
    let total_node_hours: f64 = hybrid.per_vm.iter()
        .filter(|r| r.role == NodeRole::WorkerNode)
        .map(|r| r.hours).sum();

    section("T-CF: cloud-bursting counterfactual (on-premises only)");
    let onprem = run(false);

    println!("\n{:<38} {:>10} {:>10}", "metric", "paper", "measured");
    let rows: Vec<(&str, String, String)> = vec![
        ("total duration", "05:40:00".into(), hybrid.makespan.hms()),
        ("worker CPU-hours (2 vCPU nodes)", "20".into(),
         format!("{:.1}", total_node_hours * 2.0)),
        ("AWS WN busy (h)", "9.70".into(), format!("{aws_busy:.2}")),
        ("AWS WN paid (h)", "14.70".into(), format!("{aws_paid:.2}")),
        ("AWS paid-time utilization (%)", "66".into(),
         format!("{:.0}", hybrid.paid_utilization() * 100.0)),
        ("total AWS cost ($)", "0.75".into(),
         format!("{:.2}", hybrid.total_cost_usd)),
        ("on-prem-only duration", "~09:40:00".into(),
         onprem.makespan.hms()),
        ("bursting saves (h)", "~4".into(),
         format!("{:.1}", (onprem.makespan.0 - hybrid.makespan.0)
             / 3600.0)),
    ];
    for (m, p, v) in &rows {
        println!("{m:<38} {p:>10} {v:>10}");
    }

    let mut summary = Table::new(vec!["metric", "paper", "measured"]);
    for (m, p, v) in &rows {
        summary.push(vec![m.to_string(), p.clone(), v.clone()]);
    }
    summary.write("results/cost_summary.csv").unwrap();
    println!("\nwrote results/cost_table.csv, results/cost_summary.csv");

    // Shape assertions: who wins and by roughly what factor.
    assert!(hybrid.makespan.0 < onprem.makespan.0);
    let saved_h = (onprem.makespan.0 - hybrid.makespan.0) / 3600.0;
    assert!(saved_h > 1.0, "bursting must save hours, saved {saved_h:.1}");
    assert!(hybrid.total_cost_usd < 2.0,
            "cost magnitude ~$1, got {}", hybrid.total_cost_usd);
    let util = hybrid.paid_utilization();
    assert!((0.4..0.95).contains(&util), "utilization shape: {util}");
}
