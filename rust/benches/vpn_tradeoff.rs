//! X-VPN — §3.5.6 performance–security trade-off.
//!
//! Sweeps the OpenVPN cipher choice and reports overlay throughput,
//! end-to-end latency, CP CPU cost, and transfer time for the paper's
//! 2.8 GB dataset — quantifying the advice that clusters whose software
//! already encrypts natively can drop tunnel encryption.

use evhc::netsim::{transfer_time, Cipher, LinkSpec, Network};
use evhc::sim::SimTime;
use evhc::util::bench::{bench_case, section};
use evhc::util::csv::Table;
use evhc::vrouter::Overlay;

fn main() {
    section("X-VPN: cipher sweep on the CESNET<->AWS overlay");
    let mut net = Network::new();
    let cesnet = net.add_location("cesnet");
    let aws = net.add_location("aws");
    net.set_link(cesnet, aws, LinkSpec::transatlantic());

    let dataset_bytes = 2.8e9; // the paper's 2.8 GB of audio
    let mut t = Table::new(vec!["cipher", "security", "throughput_mbps",
                                "latency_ms", "cp_cpu_per_gb_s",
                                "dataset_transfer_s"]);
    let mut tputs = Vec::new();
    for cipher in Cipher::ALL {
        let mut ov = Overlay::new(cipher);
        ov.add_central_point("fe", cesnet, 0x0A000000, SimTime(0.0))
            .unwrap();
        ov.add_site_router("vr-aws", aws, 0x0A010000, SimTime(1.0))
            .unwrap();
        let tput = ov.throughput(&net, "vr-aws", "fe", 1).unwrap();
        let lat = ov.latency(&net, "vr-aws", "fe").unwrap();
        let path = ov.element_path("vr-aws", "fe").unwrap();
        let hops = ov.hops(&net, &path).unwrap();
        let xfer = transfer_time(dataset_bytes, &hops);
        let cpu_per_gb = cipher.cpu_cost_per_byte() * 1e9;
        t.push(vec![
            cipher.name().to_string(),
            cipher.security().to_string(),
            format!("{:.0}", tput * 8.0 / 1e6),
            format!("{:.2}", lat * 1e3),
            format!("{:.2}", cpu_per_gb),
            format!("{:.1}", xfer),
        ]);
        tputs.push(tput);
    }
    print!("{}", t.to_text());
    let _ = std::fs::create_dir_all("results");
    t.write("results/vpn_tradeoff.csv").unwrap();

    // Shape: monotone — weaker cipher, more throughput; BF-CBC worst.
    assert!(tputs.windows(2).all(|w| w[0] >= w[1]),
            "throughput must decrease with cipher cost: {tputs:?}");
    assert!(tputs[0] / tputs[4] > 3.0,
            "plaintext must beat BF-CBC by >3x");

    section("CP fan-in: concurrent flows share the crypto budget");
    let mut ov = Overlay::new(Cipher::Aes256Gcm);
    ov.add_central_point("fe", cesnet, 0x0A000000, SimTime(0.0)).unwrap();
    ov.add_site_router("vr-aws", aws, 0x0A010000, SimTime(1.0)).unwrap();
    let extra = net.add_location("site3");
    net.set_link(cesnet, extra, LinkSpec::wan());
    net.set_link(aws, extra, LinkSpec::transatlantic());
    ov.add_site_router("vr-3", extra, 0x0A020000, SimTime(2.0)).unwrap();
    let mut fan = Table::new(vec!["concurrent_flows", "per_flow_mbps"]);
    for flows in [1u32, 2, 4, 8] {
        let tput = ov.throughput(&net, "vr-aws", "vr-3", flows).unwrap();
        fan.push(vec![format!("{flows}"),
                      format!("{:.0}", tput * 8.0 / 1e6)]);
    }
    print!("{}", fan.to_text());
    fan.write("results/vpn_fanin.csv").unwrap();

    section("staging ablation: node setup time vs tunnel cipher");
    // The paper's one-time node setup (udocker + 1.3 GB image pull)
    // expressed over the actual overlay path (workload::staging): cipher
    // choice and CP fan-in directly change how fast a burst node becomes
    // productive.
    let mut st = Table::new(vec!["cipher", "setup_1_pull_s",
                                 "setup_3_concurrent_s"]);
    for cipher in Cipher::ALL {
        let mut ovc = Overlay::new(cipher);
        ovc.add_central_point("fe", cesnet, 0x0A000000, SimTime(0.0))
            .unwrap();
        ovc.add_site_router("vr-aws", aws, 0x0A010000, SimTime(1.0))
            .unwrap();
        let alone = evhc::workload::StagingPath::resolve(
            &ovc, &net, "fe", "vr-aws", 1).unwrap();
        let shared = evhc::workload::StagingPath::resolve(
            &ovc, &net, "fe", "vr-aws", 3).unwrap();
        st.push(vec![cipher.name().to_string(),
                     format!("{:.0}", alone.setup_secs()),
                     format!("{:.0}", shared.setup_secs())]);
    }
    print!("{}", st.to_text());
    st.write("results/staging_ablation.csv").unwrap();

    section("micro: route resolution cost (hot path)");
    let mut sink = 0.0;
    bench_case("overlay path + hops + transfer_time", 10, 100, || {
        let path = ov.element_path("vr-aws", "vr-3").unwrap();
        let hops = ov.hops(&net, &path).unwrap();
        sink += transfer_time(1e6, &hops);
    });
    std::hint::black_box(sink);
    println!("\nwrote results/vpn_tradeoff.csv, results/vpn_fanin.csv, \
              results/staging_ablation.csv");
}
