//! X-INF — the PJRT hot path: real inference latency/throughput of the
//! AOT-compiled Pallas/JAX audio classifier served from Rust, plus the
//! DES engine's replay speed (the coordinator must never be the
//! bottleneck — DESIGN §Perf L3 target).

use evhc::cluster::{HybridCluster, RunConfig};
use evhc::runtime::{artifacts_available, ModelRuntime};
use evhc::util::bench::{bench_case, section};
use evhc::util::csv::Table;
use evhc::workload::synth_clip;

fn main() {
    let _ = std::fs::create_dir_all("results");

    if artifacts_available() {
        section("X-INF: PJRT inference latency (batch 1 vs batch 8)");
        let rt1 = ModelRuntime::load("artifacts", 1).expect("b1");
        let rt8 = ModelRuntime::load("artifacts", 8).expect("b8");
        rt1.verify_golden().expect("golden b1");
        println!("golden check OK — runtime serves the exact JAX network");

        let clip = synth_clip(0);
        let clips8: Vec<Vec<f32>> =
            (0..8).map(|i| synth_clip(i as u64)).collect();

        let mut t = Table::new(vec!["batch", "ms_per_exec",
                                    "clips_per_sec"]);
        let s1 = bench_case("infer b1", 3, 30, || {
            let _ = rt1.infer(std::slice::from_ref(&clip)).unwrap();
        });
        t.push(vec!["1".into(), format!("{:.2}", s1.mean * 1e3),
                    format!("{:.1}", 1.0 / s1.mean)]);
        let s8 = bench_case("infer b8", 3, 30, || {
            let _ = rt8.infer(&clips8).unwrap();
        });
        t.push(vec!["8".into(), format!("{:.2}", s8.mean * 1e3),
                    format!("{:.1}", 8.0 / s8.mean)]);
        print!("{}", t.to_text());
        t.write("results/inference.csv").unwrap();

        let speedup = (8.0 / s8.mean) / (1.0 / s1.mean);
        println!("batched throughput gain: {speedup:.2}x over batch-1");

        section("clip generation vs inference share");
        bench_case("synth_clip only", 3, 30, || {
            std::hint::black_box(synth_clip(17));
        });
    } else {
        println!("artifacts/ missing — run `make artifacts` for the PJRT \
                  section; continuing with DES benches only");
    }

    section("L3 coordinator: DES replay speed (full 5h40m use case)");
    let s = bench_case("full-scale use case replay", 1, 5, || {
        let mut cfg = RunConfig::paper_usecase(1.0, 42);
        cfg.inference_every = 0;
        let r = HybridCluster::new(cfg).unwrap().run().unwrap();
        assert_eq!(r.jobs_completed, 3676);
    });
    let cfg = RunConfig::paper_usecase(1.0, 42);
    let _ = cfg;
    let speedup = (5.0 * 3600.0 + 40.0 * 60.0) / s.mean;
    println!("replay speed: {speedup:.0}x real time \
              (DESIGN §Perf target ≫1000x)");
    assert!(speedup > 1000.0);

    section("DES event-queue micro-benchmark");
    bench_case("schedule+pop 100k events", 2, 10, || {
        use evhc::sim::{EventQueue, SimTime};
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..100_000u64 {
            q.schedule_at(SimTime(((i * 7919) % 100_000) as f64), i);
        }
        let mut n = 0;
        while q.pop().is_some() {
            n += 1;
        }
        assert_eq!(n, 100_000);
    });
}
