//! The indexed placement structures must be *placement-for-placement*
//! identical to the naive reference scheduler — same assignments, same
//! queue order, same node snapshots — on randomized job/node/failure
//! sequences (both placement policies), and the whole simulation must
//! stay deterministic so figure outputs are reproducible byte-for-byte.

use evhc::cluster::{HybridCluster, RunConfig, RunReport};
use evhc::lrms::core::{BatchCore, Placement};
use evhc::lrms::{JobState, NodeHealth};
use evhc::sim::SimTime;
use evhc::util::proptest::check_n;
use evhc::util::prng::Prng;

/// One randomized operation on an LRMS core.
#[derive(Debug, Clone)]
enum Op {
    Register { idx: usize, slots: u32 },
    Deregister { idx: usize },
    Health { idx: usize, health: NodeHealth },
    Submit { slots: u32 },
    Cancel,
    Schedule,
    FinishOne { ok: bool },
}

fn gen_ops(r: &mut Prng) -> Vec<Op> {
    let n = 40 + r.next_below(120) as usize;
    (0..n)
        .map(|_| match r.next_below(12) {
            0 | 1 => Op::Register {
                idx: r.next_below(12) as usize,
                slots: 1 + r.next_below(4) as u32,
            },
            2 => Op::Deregister { idx: r.next_below(12) as usize },
            3 => Op::Health {
                idx: r.next_below(12) as usize,
                health: match r.next_below(3) {
                    0 => NodeHealth::Up,
                    1 => NodeHealth::Down,
                    _ => NodeHealth::Drain,
                },
            },
            4 | 5 | 6 | 7 => Op::Submit {
                slots: 1 + r.next_below(3) as u32,
            },
            8 => Op::Cancel,
            9 | 10 => Op::Schedule,
            _ => Op::FinishOne { ok: r.chance(0.9) },
        })
        .collect()
}

/// Apply `op` to one core; return the sweep result for Schedule ops.
fn apply(c: &mut BatchCore, op: &Op, t: SimTime)
    -> Option<Vec<(u64, u32)>> {
    match op {
        Op::Register { idx, slots } => {
            c.register_node(&format!("n{idx}"), *slots, t);
            None
        }
        Op::Deregister { idx } => {
            let _ = c.deregister_node(&format!("n{idx}"), t);
            None
        }
        Op::Health { idx, health } => {
            let _ = c.set_node_health(&format!("n{idx}"), *health, t);
            None
        }
        Op::Submit { slots } => {
            c.submit("j", *slots, t);
            None
        }
        Op::Cancel => {
            // Cancel the first pending job, if any.
            let pending = c
                .jobs()
                .iter()
                .find(|j| j.state == JobState::Pending)
                .map(|j| j.id);
            if let Some(id) = pending {
                let _ = c.cancel(id, t);
            }
            None
        }
        Op::Schedule => Some(
            c.schedule(t)
                .into_iter()
                .map(|(j, n)| (j.0, n.0))
                .collect(),
        ),
        Op::FinishOne { ok } => {
            let running = c
                .jobs()
                .iter()
                .find(|j| j.state == JobState::Running)
                .map(|j| j.id);
            if let Some(id) = running {
                let _ = c.on_job_finished(id, *ok, t);
            }
            None
        }
    }
}

/// Full observable snapshot of a core, for equality checks.
fn snapshot(c: &BatchCore) -> String {
    let mut s = String::new();
    for n in c.nodes() {
        s.push_str(&format!(
            "{}:{}/{}:{:?}:{:?};",
            n.name, n.used_slots, n.slots, n.health, n.idle_since
        ));
    }
    s.push('|');
    for j in c.jobs() {
        s.push_str(&format!(
            "{}:{:?}:{:?}:{}:{:?};",
            j.id, j.state, j.node, j.requeues, j.started_at
        ));
    }
    s.push_str(&format!(
        "|pending={} running={} free={}",
        c.pending(),
        c.running(),
        c.free_slots()
    ));
    s
}

fn equivalence_for(placement: Placement) {
    check_n(
        &format!("indexed-matches-naive-{placement:?}"),
        48,
        gen_ops,
        |ops| {
            let mut indexed = BatchCore::new(placement);
            let mut naive = BatchCore::new_naive(placement);
            let mut t = 0.0;
            for (step, op) in ops.iter().enumerate() {
                t += 1.0;
                let a = apply(&mut indexed, op, SimTime(t));
                let b = apply(&mut naive, op, SimTime(t));
                if a != b {
                    return Err(format!(
                        "step {step} {op:?}: indexed {a:?} != naive {b:?}"
                    ));
                }
                let (sa, sb) = (snapshot(&indexed), snapshot(&naive));
                if sa != sb {
                    return Err(format!(
                        "step {step} {op:?}: state diverged\n  \
                         indexed: {sa}\n  naive:   {sb}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_indexed_matches_naive_pack_first_fit() {
    equivalence_for(Placement::PackFirstFit);
}

#[test]
fn prop_indexed_matches_naive_spread_most_free() {
    equivalence_for(Placement::SpreadMostFree);
}

/// Heavier smoke at a larger node count: a burst of jobs over 300 nodes
/// with failures, drained to completion on both schedulers.
#[test]
fn indexed_matches_naive_on_a_dense_burst() {
    for placement in [Placement::PackFirstFit, Placement::SpreadMostFree] {
        let mut indexed = BatchCore::new(placement);
        let mut naive = BatchCore::new_naive(placement);
        for c in [&mut indexed, &mut naive] {
            for i in 0..300u32 {
                c.register_node(&format!("wn{i}"), 1 + (i % 3),
                                SimTime(0.0));
            }
            for i in 0..1500u32 {
                c.submit("", 1 + (i % 2), SimTime(0.0));
            }
        }
        let mut t = 1.0;
        loop {
            let a = indexed.schedule(SimTime(t));
            let b = naive.schedule(SimTime(t));
            assert_eq!(a, b, "{placement:?} sweep at t={t}");
            // Inject a node failure mid-drain once.
            if (t - 3.0).abs() < 1e-9 {
                let ra = indexed
                    .set_node_health("wn7", NodeHealth::Down, SimTime(t))
                    .unwrap();
                let rb = naive
                    .set_node_health("wn7", NodeHealth::Down, SimTime(t))
                    .unwrap();
                assert_eq!(ra, rb);
            }
            let running: Vec<_> = indexed
                .jobs()
                .iter()
                .filter(|j| j.state == JobState::Running)
                .map(|j| j.id)
                .collect();
            if running.is_empty() && a.is_empty() {
                break;
            }
            for id in running {
                indexed.on_job_finished(id, true, SimTime(t + 1.0)).unwrap();
                naive.on_job_finished(id, true, SimTime(t + 1.0)).unwrap();
            }
            t += 1.0;
            assert!(t < 10_000.0, "drain did not converge");
        }
        assert_eq!(indexed.free_slots(), naive.free_slots());
        assert_eq!(indexed.pending(), naive.pending());
    }
}

fn small_run() -> RunReport {
    let mut cfg = RunConfig::paper_usecase(0.05, 42);
    cfg.inference_every = 0;
    HybridCluster::new(cfg).unwrap().run().unwrap()
}

/// The end-to-end simulation (and therefore every figure/table derived
/// from it) must be byte-identical across runs of the same seed — the
/// guarantee golden_check-style comparisons rest on.
#[test]
fn figure_outputs_byte_identical_across_runs() {
    let a = small_run();
    let b = small_run();
    assert_eq!(a.recorder.milestones, b.recorder.milestones);
    assert_eq!(
        a.recorder.fig10_usage(120.0, a.makespan).to_csv(),
        b.recorder.fig10_usage(120.0, b.makespan).to_csv()
    );
    assert_eq!(
        a.recorder.fig11_states(120.0, a.makespan).to_csv(),
        b.recorder.fig11_states(120.0, b.makespan).to_csv()
    );
    // Cost-table inputs too (§4.2 numbers).
    assert_eq!(a.total_cost_usd, b.total_cost_usd);
    assert_eq!(a.busy_secs, b.busy_secs);
}
