//! Broker policy guarantees:
//!
//! 1. The `SlaRank` policy is **decision-identical** to the legacy
//!    `orchestrator::select_site` on randomized multi-site worlds —
//!    random quotas, occupancies, availabilities, SLA books and request
//!    shapes (the tentpole's backward-compatibility proof).
//! 2. A scripted spot-preemption + site-outage scenario replays
//!    **byte-identically** across two full cluster runs: same figures,
//!    same milestones, same preemption accounting.
//! 3. The WAN chaos layer keeps both promises at once: randomized
//!    fault plans (loss, duplication, jitter, partitions) replay
//!    byte-identically across all three engines, and the self-healing
//!    paths (retransmission, provisioning retries, quarantine) still
//!    finish every job under sub-total faults.
//! 4. Correlated regional outages (fault-plan region groups and
//!    scenario `RegionalOutage` events) inherit both chaos promises:
//!    randomized regional plans replay byte-identically across all
//!    three engines and never lose a job.
//! 5. The `HealthAware` policy is **decision-identical** to `SlaRank`
//!    whenever every site's health is 1.0 — i.e. on any fault-free
//!    run — proven the same way `SlaRank` was proven against the
//!    legacy `select_site`, and again over whole fault-free cluster
//!    runs.
//! 6. The observability layer is **invisible** to all of the above:
//!    on randomized chaos runs the merged trace and metrics streams
//!    are byte-identical across all three engines, and enabling them
//!    never changes a determinism digest.

use evhc::broker::{ElasticityBroker, PolicyKind, ScenarioPlan};
use evhc::cloudsim::{CloudSite, FailureModel, Granularity, InstanceType,
                     OpLatency, Price, Provider, Quota, SiteSpec,
                     VmRequest};
use evhc::cluster::{Engine, HybridCluster, RunConfig, RunReport,
                    WanFaultPlan};
use evhc::netsim::NetId;
use evhc::obs::ObsConfig;
use evhc::orchestrator::{select_site, Sla};
use evhc::sim::SimTime;
use evhc::util::proptest::{check, check_n};
use evhc::util::prng::Prng;

/// Per-property case budget, bounded by `EVHC_PROPTEST_CASES` when set
/// (the CI quick mode caps the full-cluster properties this way).
fn cases(default: u32) -> u32 {
    std::env::var("EVHC_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

// ---------------------------------------------------------------------
// Property: SlaRank ≡ legacy select_site
// ---------------------------------------------------------------------

const NAME_POOL: [&str; 6] =
    ["alpha", "beta", "gamma", "delta", "epsilon", "zeta"];

/// Plain-data description of one randomized decision problem.
#[derive(Debug, Clone)]
struct Case {
    sites: Vec<SiteCase>,
    slas: Vec<Sla>,
    used_per_site: Vec<u32>,
    cpus: u32,
}

#[derive(Debug, Clone)]
struct SiteCase {
    name: String,
    max_vms: usize,
    max_vcpus: u32,
    availability: f64,
    usd_per_hour: f64,
    /// VMs to pre-occupy (each 2 vCPUs; requests over quota just fail).
    occupied: u32,
}

fn gen_case(r: &mut Prng) -> Case {
    let n = 2 + r.next_below(5) as usize; // 2..=6 sites
    let sites = (0..n)
        .map(|i| SiteCase {
            name: NAME_POOL[i].to_string(),
            max_vms: r.next_below(6) as usize,
            max_vcpus: r.next_below(12) as u32,
            availability: r.uniform(0.3, 1.0),
            usd_per_hour: r.uniform(0.0, 0.1),
            occupied: r.next_below(6) as u32,
        })
        .collect();
    let mut slas = Vec::new();
    for i in 0..n {
        if r.chance(0.7) {
            slas.push(Sla {
                site_name: NAME_POOL[i].to_string(),
                priority: r.next_below(4) as u32,
                max_instances: if r.chance(0.3) {
                    Some(r.next_below(4) as u32)
                } else {
                    None
                },
            });
        }
    }
    if r.chance(0.2) {
        // An SLA for a site that is not part of this world.
        slas.push(Sla {
            site_name: "elsewhere".into(),
            priority: 0,
            max_instances: Some(3),
        });
    }
    Case {
        sites,
        slas,
        used_per_site: (0..n).map(|_| r.next_below(5) as u32).collect(),
        cpus: 1 + r.next_below(3) as u32,
    }
}

fn build_sites(case: &Case) -> Vec<CloudSite> {
    case.sites
        .iter()
        .enumerate()
        .map(|(i, sc)| {
            let spec = SiteSpec {
                name: sc.name.clone(),
                provider: Provider::OpenStack,
                region: "prop".into(),
                instance_types: vec![InstanceType {
                    name: "m".into(),
                    vcpus: 2,
                    mem_gb: 4.0,
                    price: Price {
                        usd_per_hour: sc.usd_per_hour,
                        granularity: Granularity::PerSecond,
                    },
                }],
                quota: Quota {
                    max_vms: sc.max_vms,
                    max_vcpus: sc.max_vcpus,
                    max_public_ips: 2,
                },
                op_latency: OpLatency {
                    vm_boot_median: 100.0,
                    vm_boot_sigma: 0.2,
                    network_create: 5.0,
                    terminate: 30.0,
                },
                failure: FailureModel::none(),
                supports_private_networks: true,
                availability: sc.availability,
            };
            let mut site = CloudSite::new(spec, i as u8, NetId(i), 11 + i
                                          as u64);
            for k in 0..sc.occupied {
                // Over-quota requests simply fail; occupancy lands
                // wherever the quota allows.
                let _ = site.request_vm(&VmRequest {
                    name: format!("occ-{k}"),
                    instance_type: "m".into(),
                    network: None,
                    public_ip: false,
                }, SimTime(0.0));
            }
            site
        })
        .collect()
}

#[test]
fn sla_rank_is_decision_identical_to_legacy_select_site() {
    check("sla-rank ≡ select_site", gen_case, |case| {
        let sites = build_sites(case);
        let legacy = select_site(&sites, &case.slas, &case.used_per_site,
                                 case.cpus);
        let mut broker = ElasticityBroker::new(
            PolicyKind::SlaRank, &sites, &case.slas, 2, 4.0);
        let ours = broker.select(&sites, &case.used_per_site, case.cpus,
                                 0, SimTime(0.0));
        if legacy == ours {
            Ok(())
        } else {
            Err(format!("legacy={legacy:?} broker={ours:?}"))
        }
    });
}

#[test]
fn sla_rank_equivalence_holds_as_occupancy_evolves() {
    // Walk one world through a sequence of placements, applying each
    // decision (request a VM at the chosen site) — the two selectors
    // must agree at every step, not just on fresh worlds.
    let mut r = Prng::new(0xB20C);
    for round in 0..20 {
        let case = gen_case(&mut r);
        let mut sites = build_sites(&case);
        let mut broker = ElasticityBroker::new(
            PolicyKind::SlaRank, &sites, &case.slas, 2, 4.0);
        let mut used = case.used_per_site.clone();
        for step in 0..10 {
            let legacy = select_site(&sites, &case.slas, &used, case.cpus);
            let ours = broker.select(&sites, &used, case.cpus, 0,
                                     SimTime(step as f64));
            assert_eq!(legacy, ours, "round {round} step {step}");
            let Some(i) = ours else { break };
            let _ = sites[i].request_vm(&VmRequest {
                name: format!("wn-{round}-{step}"),
                instance_type: "m".into(),
                network: None,
                public_ip: false,
            }, SimTime(step as f64));
            used[i] += 1;
        }
    }
}

// ---------------------------------------------------------------------
// Property: HealthAware ≡ SlaRank when every site is fully healthy
// ---------------------------------------------------------------------

#[test]
fn health_aware_is_decision_identical_to_sla_rank_when_fault_free() {
    // A fresh broker starts every site at health 1.0, where the
    // health penalties vanish exactly — so on the same randomized
    // worlds that proved SlaRank against the legacy selector, the two
    // policies must agree decision for decision, including as
    // occupancy evolves.
    check("health-aware ≡ sla-rank (fault-free)", gen_case, |case| {
        let mut sites_a = build_sites(case);
        let mut sites_b = build_sites(case);
        let mut sla = ElasticityBroker::new(
            PolicyKind::SlaRank, &sites_a, &case.slas, 2, 4.0);
        let mut hw = ElasticityBroker::new(
            PolicyKind::HealthAware, &sites_b, &case.slas, 2, 4.0);
        let mut used = case.used_per_site.clone();
        for step in 0..10 {
            let t = SimTime(step as f64);
            let a = sla.select(&sites_a, &used, case.cpus, 0, t);
            let b = hw.select(&sites_b, &used, case.cpus, 0, t);
            if a != b {
                return Err(format!(
                    "step {step}: sla={a:?} health-aware={b:?}"));
            }
            let Some(i) = a else { break };
            for sites in [&mut sites_a, &mut sites_b] {
                let _ = sites[i].request_vm(&VmRequest {
                    name: format!("wn-{step}"),
                    instance_type: "m".into(),
                    network: None,
                    public_ip: false,
                }, t);
            }
            used[i] += 1;
        }
        Ok(())
    });
}

#[test]
fn health_aware_matches_sla_rank_over_a_fault_free_cluster_run() {
    // Whole-run equivalence: with no fault source configured the
    // health score never leaves 1.0, so a HealthAware run is the
    // SlaRank run — byte for byte, policy label aside.
    let run = |policy: PolicyKind| {
        let mut cfg = RunConfig::paper_usecase(0.05, 5);
        cfg.inference_every = 0;
        cfg.policy = policy;
        HybridCluster::new(cfg).unwrap().run().unwrap()
    };
    let a = run(PolicyKind::SlaRank);
    let b = run(PolicyKind::HealthAware);
    assert!(a.site_health.iter().all(|&h| h == 1.0));
    let mut da = a.determinism_digest();
    let mut db = b.determinism_digest();
    assert_eq!(da.policy, "sla-rank");
    assert_eq!(db.policy, "health-aware");
    da.policy = "";
    db.policy = "";
    assert_eq!(da, db);
}

// ---------------------------------------------------------------------
// Determinism: scripted preemption scenarios replay byte-identically
// ---------------------------------------------------------------------

fn scenario_cfg() -> RunConfig {
    let mut cfg = RunConfig::paper_usecase(0.1, 7);
    cfg.inference_every = 0;
    // Spot wave over CESNET mid-block-1, then an AWS outage window.
    cfg.scenario = ScenarioPlan::new()
        .spot_wave(0, 600.0, 0)
        .site_outage(1, 1500.0, 1200.0);
    cfg
}

/// The shared bit-exact replay contract (see `RunDigest` in the
/// cluster module) — one definition for every determinism check here.
fn digest(r: &RunReport) -> evhc::cluster::RunDigest {
    r.determinism_digest()
}

#[test]
fn spot_scenario_replays_byte_identically() {
    let r1 = HybridCluster::new(scenario_cfg()).unwrap().run().unwrap();
    let r2 = HybridCluster::new(scenario_cfg()).unwrap().run().unwrap();
    // The wave must actually have reclaimed capacity, and every
    // requeued job must have recovered.
    assert!(r1.preempted_vms >= 1);
    assert_eq!(r1.preempt_recovered, r1.preempted_jobs);
    assert_eq!(digest(&r1), digest(&r2));
    // Figure output — the recorder streams — is byte-identical too.
    let f10a = r1.recorder.fig10_usage(60.0, r1.makespan).to_csv();
    let f10b = r2.recorder.fig10_usage(60.0, r2.makespan).to_csv();
    assert_eq!(f10a, f10b);
    let f11a = r1.recorder.fig11_states(60.0, r1.makespan).to_csv();
    let f11b = r2.recorder.fig11_states(60.0, r2.makespan).to_csv();
    assert_eq!(f11a, f11b);
}

// ---------------------------------------------------------------------
// Property: Serial ≡ Sharded ≡ Stealing on the real paper use case
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
struct EngineCase {
    scale: f64,
    seed: u64,
    n_sites: usize,
    serialized: bool,
    /// 0 = spot wave, 1 = site outage, 2 = both.
    scenario_kind: u8,
    outage_site: usize,
}

fn engine_case(r: &mut Prng) -> EngineCase {
    let n_sites = 2 + r.next_below(3) as usize; // 2..=4
    EngineCase {
        scale: r.uniform(0.02, 0.06),
        seed: r.next_u64(),
        n_sites,
        serialized: r.chance(0.5),
        scenario_kind: r.next_below(3) as u8,
        outage_site: r.next_below(n_sites as u64) as usize,
    }
}

fn engine_case_cfg(case: &EngineCase, engine: Engine) -> RunConfig {
    let mut cfg =
        RunConfig::paper_usecase_sites(case.scale, case.seed,
                                       case.n_sites);
    cfg.inference_every = 0;
    cfg.serialized_orchestrator = case.serialized;
    cfg.engine = engine;
    let mut plan = ScenarioPlan::new();
    if case.scenario_kind != 1 {
        plan = plan.spot_wave(0, 600.0, 0);
    }
    if case.scenario_kind != 0 {
        plan = plan.site_outage(case.outage_site, 900.0, 1800.0);
    }
    cfg.scenario = plan;
    cfg
}

/// The tentpole acceptance property: `HybridCluster::run` under
/// `Engine::Serial`, `Sharded` and `Stealing` produces byte-identical
/// fig10/fig11 CSV and equal `RunReport`s on randomized paper-use-case
/// configs (spot-wave and site-outage broker failure scenarios
/// included), over 2–4 sites with both orchestrator modes.
#[test]
fn scenario_replays_byte_identically_on_all_engines() {
    check_n("serial ≡ sharded ≡ stealing (paper use case)", cases(10),
            engine_case, |case| {
        let run = |engine: Engine| -> Result<RunReport, String> {
            HybridCluster::new(engine_case_cfg(case, engine))
                .map_err(|e| e.to_string())?
                .run()
                .map_err(|e| e.to_string())
        };
        let reference = run(Engine::Serial)?;
        let total = engine_case_cfg(case, Engine::Serial)
            .workload
            .total_jobs();
        if reference.jobs_completed != total {
            return Err(format!("serial completed {}/{total}",
                               reference.jobs_completed));
        }
        let ref_digest = reference.determinism_digest();
        let until = reference.makespan;
        let f10 = reference.recorder.fig10_usage(120.0, until).to_csv();
        let f11 = reference.recorder.fig11_states(120.0, until).to_csv();
        for engine in [Engine::Sharded { threads: 0 },
                       Engine::Stealing { threads: 0 }] {
            let r = run(engine)?;
            if r.determinism_digest() != ref_digest {
                return Err(format!("{} run diverged from serial",
                                   engine.label()));
            }
            if r.recorder.transitions_named()
                != reference.recorder.transitions_named()
            {
                return Err(format!("{} recorder transitions diverged",
                                   engine.label()));
            }
            if r.recorder.fig10_usage(120.0, until).to_csv() != f10 {
                return Err(format!("{} fig10 diverged", engine.label()));
            }
            if r.recorder.fig11_states(120.0, until).to_csv() != f11 {
                return Err(format!("{} fig11 diverged", engine.label()));
            }
        }
        Ok(())
    });
}

/// Spill-mode scenario replay under the parallel engines reproduces
/// the serial in-memory recorder byte for byte (figures included).
#[test]
fn scenario_spill_replays_match_across_engines() {
    let mem = HybridCluster::new(scenario_cfg()).unwrap().run().unwrap();
    let until = mem.makespan;
    for (i, engine) in [Engine::Sharded { threads: 0 },
                        Engine::Stealing { threads: 0 }]
        .into_iter()
        .enumerate()
    {
        let dir = std::env::temp_dir()
            .join(format!("evhc_broker_engine_spill_{i}"));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = scenario_cfg();
        cfg.engine = engine;
        cfg.metrics_spill_dir = Some(dir.clone());
        let r = HybridCluster::new(cfg).unwrap().run().unwrap();
        assert_eq!(digest(&r), digest(&mem), "{}", engine.label());
        assert_eq!(r.recorder.fig10_usage(60.0, until).to_csv(),
                   mem.recorder.fig10_usage(60.0, until).to_csv());
        assert_eq!(r.recorder.fig11_states(60.0, until).to_csv(),
                   mem.recorder.fig11_states(60.0, until).to_csv());
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn every_policy_survives_the_scenario_suite() {
    for kind in PolicyKind::ALL {
        let mut cfg = scenario_cfg();
        cfg.policy = kind;
        let total = cfg.workload.total_jobs();
        let report = HybridCluster::new(cfg).unwrap().run().unwrap();
        assert_eq!(report.jobs_completed, total, "{kind:?}");
        assert_eq!(report.preempt_recovered, report.preempted_jobs,
                   "{kind:?}");
    }
}

// ---------------------------------------------------------------------
// WAN chaos: fault plans replay byte-identically and never lose work
// ---------------------------------------------------------------------

/// Plain-data description of one randomized chaos run. Fault windows
/// never target site 0 — the paper configurations place the front end
/// there, and FE-targeting plans are rejected (tested separately).
#[derive(Debug, Clone)]
struct ChaosCase {
    scale: f64,
    seed: u64,
    fault_seed: u64,
    n_sites: usize,
    /// Also give site 1 a steady 2% message-loss floor.
    steady_loss: bool,
    /// `(kind, site, at, duration, magnitude)` with kind 0 = loss,
    /// 1 = duplication, 2 = jitter, 3 = partition.
    windows: Vec<(u8, usize, f64, f64, f64)>,
}

fn chaos_case(r: &mut Prng) -> ChaosCase {
    let n_sites = 2 + r.next_below(2) as usize; // 2..=3
    let windows = (0..1 + r.next_below(3) as usize)
        .map(|_| {
            let kind = r.next_below(4) as u8;
            let site = 1 + r.next_below(n_sites as u64 - 1) as usize;
            let at = r.uniform(120.0, 2400.0);
            let duration = r.uniform(120.0, 900.0);
            let magnitude = match kind {
                0 => r.uniform(0.05, 0.6), // loss probability
                1 => r.uniform(0.1, 0.5),  // duplication probability
                2 => r.uniform(1.0, 60.0), // jitter seconds
                _ => 0.0,                  // partition needs none
            };
            (kind, site, at, duration, magnitude)
        })
        .collect();
    ChaosCase {
        scale: r.uniform(0.02, 0.05),
        seed: r.next_u64(),
        fault_seed: r.next_u64(),
        n_sites,
        steady_loss: r.chance(0.5),
        windows,
    }
}

fn chaos_cfg(case: &ChaosCase, engine: Engine) -> RunConfig {
    let mut cfg = RunConfig::paper_usecase_sites(case.scale, case.seed,
                                                 case.n_sites);
    cfg.inference_every = 0;
    cfg.engine = engine;
    let mut plan = WanFaultPlan::new(case.fault_seed);
    for &(kind, site, at, duration, magnitude) in &case.windows {
        plan = match kind {
            0 => plan.lossy(site, at, duration, magnitude),
            1 => plan.duplicating(site, at, duration, magnitude),
            2 => plan.jittery(site, at, duration, magnitude),
            _ => plan.partition(site, at, duration),
        };
    }
    cfg.faults = plan;
    if case.steady_loss {
        cfg.sites[1].failure.message_loss_prob = 0.02;
    }
    cfg
}

/// The chaos acceptance property: randomized WAN fault plans replay
/// byte-identically across the serial, sharded and stealing engines —
/// the per-message `(site, seq)` fault streams make all three replays
/// drop, duplicate and delay exactly the same messages — and the run
/// still completes every job, because sub-total loss plus bounded
/// partitions can delay work but never lose it.
#[test]
fn chaos_plans_replay_byte_identically_on_all_engines() {
    check_n("wan chaos (serial ≡ sharded ≡ stealing)", cases(6),
            chaos_case, |case| {
        let run = |engine: Engine| -> Result<RunReport, String> {
            HybridCluster::new(chaos_cfg(case, engine))
                .map_err(|e| e.to_string())?
                .run()
                .map_err(|e| e.to_string())
        };
        let reference = run(Engine::Serial)?;
        let total = chaos_cfg(case, Engine::Serial)
            .workload
            .total_jobs();
        if reference.jobs_completed != total {
            return Err(format!("serial completed {}/{total} under chaos",
                               reference.jobs_completed));
        }
        let ref_digest = reference.determinism_digest();
        for engine in [Engine::Sharded { threads: 0 },
                       Engine::Stealing { threads: 0 }] {
            let r = run(engine)?;
            if r.determinism_digest() != ref_digest {
                return Err(format!("{} diverged under chaos",
                                   engine.label()));
            }
        }
        Ok(())
    });
}

/// Plain-data description of one randomized correlated-outage run.
/// Members never include site 0 — the paper configurations place the
/// front end there, and FE-targeting plans are rejected (tested
/// separately).
#[derive(Debug, Clone)]
struct RegionalCase {
    scale: f64,
    seed: u64,
    fault_seed: u64,
    n_sites: usize,
    /// true = scenario `RegionalOutage`, false = fault-plan region
    /// group — the two spellings of the same correlated failure.
    via_scenario: bool,
    /// Deduplicated non-FE member sites (≥ 1).
    members: Vec<usize>,
    at: f64,
    duration: f64,
    /// Also run a loss window on site 1, so the regional window has to
    /// compose with ordinary per-site faults.
    extra_loss: bool,
}

fn regional_case(r: &mut Prng) -> RegionalCase {
    let n_sites = 3 + r.next_below(2) as usize; // 3..=4
    let mut members: Vec<usize> = (1..n_sites)
        .filter(|_| r.chance(0.7))
        .collect();
    if members.is_empty() {
        members.push(1 + r.next_below(n_sites as u64 - 1) as usize);
    }
    RegionalCase {
        scale: r.uniform(0.02, 0.05),
        seed: r.next_u64(),
        fault_seed: r.next_u64(),
        n_sites,
        via_scenario: r.chance(0.5),
        members,
        at: r.uniform(300.0, 1500.0),
        duration: r.uniform(300.0, 1200.0),
        extra_loss: r.chance(0.5),
    }
}

fn regional_cfg(case: &RegionalCase, engine: Engine) -> RunConfig {
    let mut cfg = RunConfig::paper_usecase_sites(case.scale, case.seed,
                                                 case.n_sites);
    cfg.inference_every = 0;
    cfg.engine = engine;
    let mut plan = WanFaultPlan::new(case.fault_seed);
    if case.extra_loss {
        plan = plan.lossy(1, 0.0, 1000.0, 0.1);
    }
    if case.via_scenario {
        cfg.scenario = ScenarioPlan::new()
            .regional_outage(&case.members, case.at, case.duration);
    } else {
        plan = plan.regional_outage(&case.members, case.at,
                                    case.duration);
    }
    cfg.faults = plan;
    cfg
}

/// The correlated-outage acceptance property: a randomized regional
/// outage — one backbone failure partitioning several sites at once,
/// spelled either as a fault-plan region group or as a scenario
/// `RegionalOutage` — resolves into the same per-site `(site, seq)`
/// fault streams on every engine, so the replay stays byte-identical,
/// the per-member window accounting agrees with the plan, and every
/// job still completes.
#[test]
fn regional_outage_plans_replay_byte_identically_on_all_engines() {
    check_n("regional outage (serial ≡ sharded ≡ stealing)", cases(4),
            regional_case, |case| {
        let run = |engine: Engine| -> Result<RunReport, String> {
            HybridCluster::new(regional_cfg(case, engine))
                .map_err(|e| e.to_string())?
                .run()
                .map_err(|e| e.to_string())
        };
        let reference = run(Engine::Serial)?;
        let total = regional_cfg(case, Engine::Serial)
            .workload
            .total_jobs();
        if reference.jobs_completed != total {
            return Err(format!(
                "serial completed {}/{total} under a regional outage",
                reference.jobs_completed));
        }
        if reference.regional_windows as usize != case.members.len() {
            return Err(format!(
                "{} regional windows installed for {} members",
                reference.regional_windows, case.members.len()));
        }
        let ref_digest = reference.determinism_digest();
        for engine in [Engine::Sharded { threads: 0 },
                       Engine::Stealing { threads: 0 }] {
            let r = run(engine)?;
            if r.determinism_digest() != ref_digest {
                return Err(format!(
                    "{} diverged under a regional outage",
                    engine.label()));
            }
        }
        Ok(())
    });
}

/// Sustained sub-total loss on the busy site: every dropped report is
/// retransmitted until it lands, so the cluster still finishes the
/// full workload — and the chaos accounting proves the faults
/// actually fired rather than the plan being silently inert.
#[test]
fn cluster_completes_under_sustained_message_loss() {
    let cfg = || {
        let mut cfg = RunConfig::paper_usecase(0.05, 11);
        cfg.inference_every = 0;
        cfg.faults = WanFaultPlan::new(0xC4A0)
            .lossy(1, 0.0, 20_000.0, 0.25)
            .duplicating(1, 0.0, 20_000.0, 0.15);
        cfg
    };
    let total = cfg().workload.total_jobs();
    let r1 = HybridCluster::new(cfg()).unwrap().run().unwrap();
    assert_eq!(r1.jobs_completed, total);
    assert!(r1.messages_dropped > 0, "loss window never fired");
    assert!(r1.messages_retransmitted > 0, "no retransmissions");
    assert!(r1.messages_duplicated > 0, "dup window never fired");
    // The chaos accounting is part of the replay contract too.
    let r2 = HybridCluster::new(cfg()).unwrap().run().unwrap();
    assert_eq!(digest(&r1), digest(&r2));
}

/// A scripted WAN partition long enough to trip the missed-heartbeat
/// circuit breaker: the silent site is quarantined, its leased jobs
/// are requeued, and once the partition heals the quarantine closes
/// and every requeued job recovers.
#[test]
fn partition_trips_quarantine_and_recovers() {
    let cfg = || {
        let mut cfg = RunConfig::paper_usecase(0.1, 7);
        cfg.inference_every = 0;
        // 900 s of silence = 15 missed 60 s CLUES heartbeat scans,
        // far past the default quarantine threshold of 3.
        cfg.faults = WanFaultPlan::new(9).partition(1, 1500.0, 900.0);
        cfg
    };
    let total = cfg().workload.total_jobs();
    let r = HybridCluster::new(cfg()).unwrap().run().unwrap();
    assert_eq!(r.jobs_completed, total);
    assert!(r.quarantine_windows >= 1, "breaker never tripped");
    assert!(r.quarantine_secs > 0.0);
    assert_eq!(r.lease_recovered_jobs, r.lease_requeued_jobs,
               "a requeued lease never recovered");
    assert!(r.messages_dropped > 0);
}

/// Malformed fault plans fail fast with a clear error instead of
/// silently misbehaving mid-run: out-of-range site indices are
/// rejected at construction, front-end targeting when the workload
/// begins (the FE site is only known once the front end is placed).
#[test]
fn fault_plan_validation_rejects_bad_targets() {
    let mut cfg = RunConfig::paper_usecase(0.05, 1);
    cfg.faults = WanFaultPlan::new(1).lossy(7, 0.0, 100.0, 0.1);
    let err = HybridCluster::new(cfg).err().expect("must reject");
    assert!(err.to_string().contains("site 7"), "{err}");

    let mut cfg = RunConfig::paper_usecase(0.05, 1);
    cfg.inference_every = 0;
    cfg.faults = WanFaultPlan::new(1).lossy(0, 0.0, 100.0, 0.1);
    let err = HybridCluster::new(cfg)
        .unwrap()
        .run()
        .err()
        .expect("must reject");
    assert!(err.to_string().contains("front end"), "{err}");
}

// ---------------------------------------------------------------------
// Property: deterministic observability (trace/metrics streams)
// ---------------------------------------------------------------------

/// Tracing and metrics on a randomized chaos run export byte-identical
/// streams from all three engines: every span and instant is emitted
/// at a deterministic `(time, shard, seq)` position, so the merged
/// Chrome trace JSON, the trace CSV and the metrics CSV never depend
/// on how the run was parallelized. The JSON must also parse.
#[test]
fn trace_streams_are_byte_identical_across_engines() {
    check_n("obs streams (serial ≡ sharded ≡ stealing)", cases(5),
            chaos_case, |case| {
        let run = |engine: Engine| -> Result<RunReport, String> {
            let mut cfg = chaos_cfg(case, engine);
            cfg.obs = ObsConfig::enabled();
            HybridCluster::new(cfg)
                .map_err(|e| e.to_string())?
                .run()
                .map_err(|e| e.to_string())
        };
        let reference = run(Engine::Serial)?;
        let trace = reference
            .trace
            .as_ref()
            .ok_or("serial run recorded no trace")?;
        let metrics = reference
            .metrics
            .as_ref()
            .ok_or("serial run sampled no metrics")?;
        if trace.is_empty() || metrics.is_empty() {
            return Err("empty observability streams".to_string());
        }
        let json = trace.to_chrome_json();
        evhc::api::json::parse(&json)
            .map_err(|e| format!("invalid chrome trace JSON: {e:?}"))?;
        let csv = trace.to_csv();
        let mcsv = metrics.to_csv();
        for engine in [Engine::Sharded { threads: 0 },
                       Engine::Stealing { threads: 0 }] {
            let r = run(engine)?;
            let tr = r.trace.as_ref().ok_or("missing trace")?;
            let m = r.metrics.as_ref().ok_or("missing metrics")?;
            if tr.to_chrome_json() != json || tr.to_csv() != csv {
                return Err(format!("{} trace diverged",
                                   engine.label()));
            }
            if m.to_csv() != mcsv {
                return Err(format!("{} metrics diverged",
                                   engine.label()));
            }
        }
        Ok(())
    });
}

/// Enabling observability cannot perturb the simulation: on every
/// engine, the determinism digest of a traced chaos run equals the
/// digest of the identical run with recording off.
#[test]
fn trace_recording_is_digest_neutral() {
    check_n("obs digest-neutrality", cases(3), chaos_case, |case| {
        for engine in [Engine::Serial, Engine::Sharded { threads: 0 },
                       Engine::Stealing { threads: 0 }] {
            let run = |obs: bool| -> Result<RunReport, String> {
                let mut cfg = chaos_cfg(case, engine);
                if obs {
                    cfg.obs = ObsConfig::enabled();
                }
                HybridCluster::new(cfg)
                    .map_err(|e| e.to_string())?
                    .run()
                    .map_err(|e| e.to_string())
            };
            let off = run(false)?;
            let on = run(true)?;
            if on.determinism_digest() != off.determinism_digest() {
                return Err(format!("tracing perturbed the {} digest",
                                   engine.label()));
            }
            if on.trace.is_none() || on.metrics.is_none() {
                return Err("traced run returned no streams".to_string());
            }
        }
        Ok(())
    });
}
