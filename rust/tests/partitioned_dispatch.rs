//! Partitioned dispatch acceptance suite.
//!
//! 1. With `RunConfig::dispatch = Partitioned` the run replays
//!    byte-identically across the serial, sharded and stealing
//!    engines — `RunReport::determinism_digest()`, recorder streams
//!    and figure CSVs — on randomized multi-site paper workloads,
//!    with and without WAN chaos.
//! 2. The partitioned dispatcher places the same workload the
//!    centralized reference places: every submitted job completes
//!    exactly once in both modes (the two-phase lease protocol never
//!    double-places and never loses a job), on randomized configs.
//! 3. Spillover arbitration edge cases: a site returning a whole
//!    block after losing its capacity, every worker site quarantined
//!    at once, and spillover re-routed towards a site that goes dark
//!    in the same window — each drained to completion and
//!    byte-compared across all three engines.
//!
//! `EVHC_PROPTEST_CASES` bounds every property's case count (the CI
//! quick mode sets it low; unset, each property uses its own default).

use evhc::broker::ScenarioPlan;
use evhc::cluster::{DispatchMode, Engine, HybridCluster, RunConfig,
                    RunReport, WanFaultPlan};
use evhc::util::proptest::check_n;
use evhc::util::prng::Prng;

/// Per-property case budget, bounded by `EVHC_PROPTEST_CASES` when set
/// (the CI quick mode caps the full-cluster properties this way).
fn cases(default: u32) -> u32 {
    std::env::var("EVHC_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn run(cfg: RunConfig) -> Result<RunReport, String> {
    HybridCluster::new(cfg)
        .map_err(|e| e.to_string())?
        .run()
        .map_err(|e| e.to_string())
}

/// Serial reference vs sharded and stealing replays of `mk(engine)`:
/// digests, recorder transition streams and figure CSVs must all be
/// byte-identical, and the serial run must drain the whole workload.
fn three_engine_identity(
    mk: &dyn Fn(Engine) -> RunConfig,
    what: &str,
) -> Result<RunReport, String> {
    let reference = run(mk(Engine::Serial))?;
    let total = mk(Engine::Serial).workload.total_jobs();
    if reference.jobs_completed != total {
        return Err(format!("{what}: serial completed {}/{total}",
                           reference.jobs_completed));
    }
    if reference.recorder.job_runs.len() != total as usize {
        return Err(format!(
            "{what}: serial recorded {} job runs for {total} jobs",
            reference.recorder.job_runs.len()));
    }
    let ref_digest = reference.determinism_digest();
    let until = reference.makespan;
    let f10 = reference.recorder.fig10_usage(120.0, until).to_csv();
    let f11 = reference.recorder.fig11_states(120.0, until).to_csv();
    for engine in [Engine::Sharded { threads: 0 },
                   Engine::Stealing { threads: 0 }] {
        let r = run(mk(engine))?;
        if r.determinism_digest() != ref_digest {
            return Err(format!("{what}: {} diverged from serial",
                               engine.label()));
        }
        if r.recorder.transitions_named()
            != reference.recorder.transitions_named()
        {
            return Err(format!("{what}: {} transitions diverged",
                               engine.label()));
        }
        if r.recorder.fig10_usage(120.0, until).to_csv() != f10 {
            return Err(format!("{what}: {} fig10 diverged",
                               engine.label()));
        }
        if r.recorder.fig11_states(120.0, until).to_csv() != f11 {
            return Err(format!("{what}: {} fig11 diverged",
                               engine.label()));
        }
    }
    Ok(reference)
}

// ---------------------------------------------------------------------
// Property: Serial ≡ Sharded ≡ Stealing under partitioned dispatch
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
struct PartCase {
    scale: f64,
    seed: u64,
    n_sites: usize,
    serialized: bool,
    /// 0 = clean, 1 = spot wave, 2 = site outage, 3 = both.
    scenario_kind: u8,
    outage_site: usize,
}

fn part_case(r: &mut Prng) -> PartCase {
    let n_sites = 2 + r.next_below(3) as usize; // 2..=4
    PartCase {
        scale: r.uniform(0.02, 0.06),
        seed: r.next_u64(),
        n_sites,
        serialized: r.chance(0.5),
        scenario_kind: r.next_below(4) as u8,
        outage_site: r.next_below(n_sites as u64) as usize,
    }
}

fn part_cfg(case: &PartCase, engine: Engine) -> RunConfig {
    let mut cfg = RunConfig::paper_usecase_sites(case.scale, case.seed,
                                                 case.n_sites);
    cfg.inference_every = 0;
    cfg.serialized_orchestrator = case.serialized;
    cfg.engine = engine;
    cfg.dispatch = DispatchMode::Partitioned;
    let mut plan = ScenarioPlan::new();
    if case.scenario_kind == 1 || case.scenario_kind == 3 {
        plan = plan.spot_wave(0, 600.0, 0);
    }
    if case.scenario_kind == 2 || case.scenario_kind == 3 {
        plan = plan.site_outage(case.outage_site, 900.0, 1800.0);
    }
    cfg.scenario = plan;
    cfg
}

/// The tentpole acceptance property: partitioned dispatch replays
/// byte-identically across all three engines on randomized paper
/// configs, scenario failures included, and drains every job.
#[test]
fn prop_partitioned_replays_byte_identically_on_all_engines() {
    check_n("partitioned (serial ≡ sharded ≡ stealing)", cases(8),
            part_case, |case| {
        three_engine_identity(&|engine| part_cfg(case, engine),
                              "partitioned")
            .map(|_| ())
    });
}

/// Same property under randomized WAN chaos: fault windows (loss,
/// duplication, jitter, partitions that trip the heartbeat breaker)
/// target worker sites while blocks are in flight, and the three
/// replays must still not differ in a single byte — the lease
/// protocol drops every stale zombie report identically.
#[test]
fn prop_partitioned_chaos_replays_byte_identically() {
    #[derive(Debug, Clone)]
    struct ChaosCase {
        part: PartCase,
        fault_seed: u64,
        /// `(kind, site, at, duration, magnitude)`, kind 0 = loss,
        /// 1 = duplication, 2 = jitter, 3 = partition.
        windows: Vec<(u8, usize, f64, f64, f64)>,
    }
    let gen = |r: &mut Prng| {
        let mut part = part_case(r);
        part.n_sites = 2 + r.next_below(2) as usize; // 2..=3
        part.scenario_kind = 0;
        let windows = (0..1 + r.next_below(3) as usize)
            .map(|_| {
                let kind = r.next_below(4) as u8;
                let site = 1
                    + r.next_below(part.n_sites as u64 - 1) as usize;
                let at = r.uniform(120.0, 2400.0);
                let duration = r.uniform(120.0, 900.0);
                let magnitude = match kind {
                    0 => r.uniform(0.05, 0.5),
                    1 => r.uniform(0.1, 0.5),
                    2 => r.uniform(1.0, 60.0),
                    _ => 0.0,
                };
                (kind, site, at, duration, magnitude)
            })
            .collect();
        ChaosCase { part, fault_seed: r.next_u64(), windows }
    };
    check_n("partitioned wan chaos", cases(4), gen, |case| {
        let mk = |engine: Engine| {
            let mut cfg = part_cfg(&case.part, engine);
            let mut plan = WanFaultPlan::new(case.fault_seed);
            for &(kind, site, at, dur, mag) in &case.windows {
                plan = match kind {
                    0 => plan.lossy(site, at, dur, mag),
                    1 => plan.duplicating(site, at, dur, mag),
                    2 => plan.jittery(site, at, dur, mag),
                    _ => plan.partition(site, at, dur),
                };
            }
            cfg.faults = plan;
            cfg
        };
        let r = three_engine_identity(&mk, "partitioned-chaos")?;
        // Revoked leases all recovered: nothing double-placed, nothing
        // lost to a zombie site.
        if r.lease_recovered_jobs != r.lease_requeued_jobs {
            return Err(format!(
                "lease recovery leaked: {} revoked, {} recovered",
                r.lease_requeued_jobs, r.lease_recovered_jobs));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Property: partitioned ≡ centralized on the workload it places
// ---------------------------------------------------------------------

/// The partitioned dispatcher is placement-equivalent to the
/// centralized reference in the sense that matters for the paper
/// figures: both modes place and complete *every* submitted job
/// exactly once (`jobs_completed` and the recorder's job-run stream
/// agree with the workload total), and each mode is individually
/// deterministic. The event timelines legitimately differ — blocks
/// ride the WAN and site-local rngs draw durations — so the digest is
/// compared within each mode (re-run) rather than across modes.
#[test]
fn prop_partitioned_places_the_same_workload_as_centralized() {
    check_n("partitioned ≡ centralized workload", cases(8), part_case,
            |case| {
        let total = part_cfg(case, Engine::Serial).workload.total_jobs();
        for mode in [DispatchMode::Centralized,
                     DispatchMode::Partitioned] {
            let mk = || {
                let mut cfg = part_cfg(case, Engine::Serial);
                cfg.dispatch = mode;
                cfg
            };
            let r = run(mk())?;
            if r.jobs_completed != total {
                return Err(format!("{mode:?} completed {}/{total}",
                                   r.jobs_completed));
            }
            if r.recorder.job_runs.len() != total as usize {
                return Err(format!(
                    "{mode:?} recorded {} runs for {total} jobs \
                     (double placement or loss)",
                    r.recorder.job_runs.len()));
            }
            if r.preempt_recovered != r.preempted_jobs {
                return Err(format!(
                    "{mode:?} preemption leaked: {} requeued, {} \
                     recovered", r.preempted_jobs,
                    r.preempt_recovered));
            }
            let again = run(mk())?;
            if again.determinism_digest() != r.determinism_digest() {
                return Err(format!("{mode:?} replay diverged"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Spillover arbitration edge cases (three engines byte-compared)
// ---------------------------------------------------------------------

/// Edge (a): a spot wave reclaims a site's workers right after blocks
/// were routed there — the site cannot place them locally, returns
/// the jobs in its barrier emission, and the dispatcher re-routes
/// them elsewhere. The wave must really have fired, every preempted
/// job must recover, and all three engines must agree byte-for-byte.
#[test]
fn whole_block_returned_when_a_spot_wave_empties_the_site() {
    let mk = |engine: Engine| {
        let mut cfg = RunConfig::paper_usecase_sites(0.08, 11, 3);
        cfg.inference_every = 0;
        cfg.engine = engine;
        cfg.dispatch = DispatchMode::Partitioned;
        // count = 0 reclaims the site's entire spot allocation.
        cfg.scenario = ScenarioPlan::new().spot_wave(0, 600.0, 0);
        cfg
    };
    let r = three_engine_identity(&mk, "spot-wave-spill")
        .expect("edge (a)");
    assert!(r.preempted_vms >= 1, "wave never reclaimed a VM");
    assert_eq!(r.preempt_recovered, r.preempted_jobs);
}

/// Edge (b): every worker site that can be partitioned goes dark at
/// once and stays dark past the heartbeat-breaker threshold. The
/// dispatcher must fall back — routing only to what remains, holding
/// the rest queued — and drain the full workload once the partitions
/// heal and the quarantines close. Byte-identical on all engines.
#[test]
fn all_sites_quarantined_falls_back_and_recovers() {
    let n_sites = 3;
    let mk = |engine: Engine| {
        let mut cfg = RunConfig::paper_usecase_sites(0.05, 23, n_sites);
        cfg.inference_every = 0;
        cfg.engine = engine;
        cfg.dispatch = DispatchMode::Partitioned;
        // Fault plans may not target site 0 (the front end), so "all
        // sites" is every remote worker site, simultaneously, for
        // long enough to blow the default breaker threshold.
        let mut plan = WanFaultPlan::new(17);
        for site in 1..n_sites {
            plan = plan.partition(site, 1200.0, 900.0);
        }
        cfg.faults = plan;
        cfg
    };
    let r = three_engine_identity(&mk, "all-quarantined")
        .expect("edge (b)");
    assert!(r.quarantine_windows >= 1, "breaker never tripped");
    assert!(r.quarantine_secs > 0.0);
    assert_eq!(r.lease_recovered_jobs, r.lease_requeued_jobs,
               "a revoked lease never recovered");
}

/// Edge (c): a spot wave forces site 1 to return its block, and the
/// natural re-route target (site 2) is partitioned in the same
/// window — the spilled jobs' second home goes dark while they are in
/// flight, its quarantine revokes them again, and they must still
/// complete exactly once. Byte-identical on all engines.
#[test]
fn spillover_rerouted_when_target_site_goes_dark_same_window() {
    let mk = |engine: Engine| {
        let mut cfg = RunConfig::paper_usecase_sites(0.06, 31, 3);
        cfg.inference_every = 0;
        cfg.engine = engine;
        cfg.dispatch = DispatchMode::Partitioned;
        cfg.scenario = ScenarioPlan::new().spot_wave(1, 600.0, 0);
        // Dark just after the spills are re-routed.
        cfg.faults = WanFaultPlan::new(5).partition(2, 620.0, 700.0);
        cfg
    };
    let r = three_engine_identity(&mk, "spill-into-dark-site")
        .expect("edge (c)");
    assert_eq!(r.preempt_recovered, r.preempted_jobs);
    assert_eq!(r.lease_recovered_jobs, r.lease_requeued_jobs);
}
