//! Property-based tests over coordinator invariants, using the in-tree
//! harness (`evhc::util::proptest`). Each property runs against dozens of
//! randomized scenarios; failures report the seed for exact reproduction.

use evhc::lrms::{HtCondor, JobState, Lrms, NodeHealth, Slurm};
use evhc::netsim::{Cipher, Network};
use evhc::orchestrator::{UpdateOp, UpdateState, WorkflowEngine};
use evhc::sim::{EventQueue, SimTime};
use evhc::util::prng::Prng;
use evhc::util::proptest::{check, check_n};
use evhc::vrouter::Overlay;

// ---------------------------------------------------------------------
// DES engine
// ---------------------------------------------------------------------

#[test]
fn prop_event_queue_dispatches_in_time_order() {
    check("event-queue-order", |r: &mut Prng| {
        let n = 1 + r.next_below(200) as usize;
        (0..n).map(|_| r.uniform(0.0, 1000.0)).collect::<Vec<f64>>()
    }, |times| {
        let mut q: EventQueue<usize> = EventQueue::new();
        for (i, &t) in times.iter().enumerate() {
            q.schedule_at(SimTime(t), i);
        }
        let mut last = f64::NEG_INFINITY;
        while let Some((t, _)) = q.pop() {
            if t.0 < last {
                return Err(format!("time went backwards: {last} -> {}",
                                   t.0));
            }
            last = t.0;
        }
        Ok(())
    });
}

#[test]
fn prop_cancelled_events_never_fire() {
    check("cancel-suppresses", |r: &mut Prng| {
        let n = 1 + r.next_below(100) as usize;
        let cancel_mask: Vec<bool> =
            (0..n).map(|_| r.chance(0.5)).collect();
        let times: Vec<f64> =
            (0..n).map(|_| r.uniform(0.0, 100.0)).collect();
        (times, cancel_mask)
    }, |(times, mask)| {
        let mut q: EventQueue<usize> = EventQueue::new();
        let ids: Vec<_> = times.iter().enumerate()
            .map(|(i, &t)| q.schedule_at(SimTime(t), i))
            .collect();
        for (id, &c) in ids.iter().zip(mask) {
            if c {
                q.cancel(*id);
            }
        }
        let mut fired = Vec::new();
        while let Some((_, i)) = q.pop() {
            fired.push(i);
        }
        for (i, &c) in mask.iter().enumerate() {
            if c && fired.contains(&i) {
                return Err(format!("cancelled event {i} fired"));
            }
            if !c && !fired.contains(&i) {
                return Err(format!("live event {i} lost"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// LRMS invariants (both plugins)
// ---------------------------------------------------------------------

/// Random op sequence on an LRMS; checks conservation + capacity.
fn lrms_invariants(mk: fn() -> Box<dyn Lrms>) {
    check_n("lrms-invariants", 48, |r: &mut Prng| {
        let ops: Vec<u64> = (0..120).map(|_| r.next_u64()).collect();
        ops
    }, |ops| {
        let mut l = mk();
        let mut t = 0.0;
        let mut submitted = 0usize;
        let mut node_i = 0usize;
        for &op in ops {
            t += 1.0;
            match op % 6 {
                0 => {
                    l.register_node(&format!("n{node_i}"),
                                    1 + (op % 3) as u32, SimTime(t));
                    node_i += 1;
                }
                1 => {
                    l.submit(&format!("j{submitted}"), 1, SimTime(t));
                    submitted += 1;
                }
                2 => {
                    l.schedule(SimTime(t));
                }
                3 => {
                    // Finish the first running job, if any.
                    let running = l.jobs().iter()
                        .find(|j| j.state == JobState::Running)
                        .map(|j| j.id);
                    if let Some(id) = running {
                        l.on_job_finished(id, true, SimTime(t)).unwrap();
                    }
                }
                4 => {
                    let names: Vec<String> = l.nodes().iter()
                        .map(|n| n.name.clone()).collect();
                    if !names.is_empty() {
                        let k = (op as usize / 7) % names.len();
                        let _ = l.set_node_health(
                            &names[k],
                            if op % 2 == 0 { NodeHealth::Down }
                            else { NodeHealth::Up },
                            SimTime(t));
                    }
                }
                _ => {
                    let names: Vec<String> = l.nodes().iter()
                        .map(|n| n.name.clone()).collect();
                    if names.len() > 1 {
                        let k = (op as usize / 11) % names.len();
                        let _ = l.deregister_node(&names[k], SimTime(t));
                    }
                }
            }
            // Invariant 1: no node oversubscribed.
            for n in l.nodes() {
                if n.used_slots > n.slots {
                    return Err(format!("{} oversubscribed", n.name));
                }
            }
            // Invariant 2: job conservation.
            let jobs = l.jobs();
            let counted = jobs.iter().filter(|j| matches!(j.state,
                JobState::Pending | JobState::Running
                | JobState::Completed | JobState::Failed
                | JobState::Cancelled)).count();
            if counted != submitted {
                return Err(format!("jobs leaked: {counted}/{submitted}"));
            }
            // Invariant 3: running jobs sit on Up nodes with capacity.
            for j in &jobs {
                if j.state == JobState::Running {
                    let nid = j.node
                        .ok_or("running job without node")?;
                    let stat = l.node_stat(nid)
                        .ok_or(format!("running on missing node {nid}"))?;
                    if stat.health == NodeHealth::Down {
                        return Err(format!("running on Down node {nid}"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_slurm_invariants() {
    lrms_invariants(|| Box::new(Slurm::new()));
}

#[test]
fn prop_condor_invariants() {
    lrms_invariants(|| Box::new(HtCondor::new()));
}

// ---------------------------------------------------------------------
// Workflow engine
// ---------------------------------------------------------------------

#[test]
fn prop_serialized_engine_never_overlaps() {
    check("engine-serialized", |r: &mut Prng| {
        (0..60).map(|_| r.next_below(3)).collect::<Vec<u64>>()
    }, |ops| {
        let mut e = WorkflowEngine::new(true);
        let mut t = 0.0;
        let mut started: Vec<evhc::orchestrator::UpdateId> = Vec::new();
        for &op in ops {
            t += 1.0;
            match op {
                0 => {
                    e.submit(UpdateOp::AddWorker {
                        name: format!("n{t}"),
                    }, SimTime(t));
                }
                1 => {
                    started.extend(e.startable(SimTime(t)).iter()
                        .map(|u| u.id));
                }
                _ => {
                    if let Some(id) = started.pop() {
                        e.complete(id, SimTime(t)).unwrap();
                    }
                }
            }
            if e.in_progress() > 1 {
                return Err(format!("{} updates in progress",
                                   e.in_progress()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_engine_updates_terminal_states_are_final() {
    check_n("engine-terminal", 32, |r: &mut Prng| {
        (0..40).map(|_| r.next_below(4)).collect::<Vec<u64>>()
    }, |ops| {
        let mut e = WorkflowEngine::new(true);
        let mut t = 0.0;
        let mut started = Vec::new();
        for &op in ops {
            t += 1.0;
            match op {
                0 => {
                    e.submit(UpdateOp::InitialDeploy, SimTime(t));
                }
                1 => started.extend(
                    e.startable(SimTime(t)).iter().map(|u| u.id)),
                2 => {
                    if let Some(id) = started.pop() {
                        e.complete(id, SimTime(t)).unwrap();
                    }
                }
                _ => {
                    // Cancel any queued update.
                    if let Some(id) = e.find_queued(|_| true) {
                        e.cancel(id, SimTime(t)).unwrap();
                    }
                }
            }
        }
        // Terminal updates must have finished_at; queued/in-progress not.
        for u in e.updates() {
            match u.state {
                UpdateState::Done | UpdateState::Cancelled => {
                    if u.finished_at.is_none() {
                        return Err(format!("{u:?} terminal w/o time"));
                    }
                }
                _ => {
                    if u.finished_at.is_some() {
                        return Err(format!("{u:?} live with finish time"));
                    }
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Overlay routing
// ---------------------------------------------------------------------

#[test]
fn prop_overlay_full_connectivity_while_cp_alive() {
    check_n("overlay-connectivity", 48, |r: &mut Prng| {
        let sites = 2 + r.next_below(6) as usize;
        let standalone = r.next_below(3) as usize;
        let shortest = r.chance(0.5);
        let cipher_i = r.next_below(5) as usize;
        (sites, standalone, shortest, cipher_i)
    }, |&(sites, standalone, shortest, cipher_i)| {
        let mut net = Network::new();
        let ids: Vec<_> = (0..sites + standalone)
            .map(|i| net.add_location(&format!("s{i}")))
            .collect();
        let mut ov = Overlay::new(Cipher::ALL[cipher_i]);
        ov.add_central_point("cp", ids[0], 0x0A000000, SimTime(0.0))
            .map_err(|e| e.to_string())?;
        let mut names = vec!["cp".to_string()];
        for (i, &loc) in ids.iter().enumerate().take(sites).skip(1) {
            let n = format!("vr{i}");
            ov.add_site_router(&n, loc, 0x0A000000 + ((i as u32) << 8),
                               SimTime(1.0))
                .map_err(|e| e.to_string())?;
            names.push(n);
        }
        for (i, &loc) in ids.iter().enumerate().skip(sites) {
            let n = format!("sa{i}");
            ov.add_standalone(&n, loc, SimTime(2.0))
                .map_err(|e| e.to_string())?;
            names.push(n);
        }
        ov.shortest_path = shortest;
        // Invariant: every pair is connected, and latency is symmetric-ish
        // (same path length both ways).
        for a in &names {
            for b in &names {
                if !ov.is_connected(a, b) {
                    return Err(format!("{a} !-> {b}"));
                }
                let lab = ov.latency(&net, a, b).unwrap();
                let lba = ov.latency(&net, b, a).unwrap();
                if (lab - lba).abs() > 1e-9 {
                    return Err(format!("asymmetric {a}<->{b}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_redundant_star_survives_any_single_cp_failure() {
    check_n("overlay-failover", 32, |r: &mut Prng| {
        let routers = 1 + r.next_below(5) as usize;
        let fail_primary = r.chance(0.5);
        (routers, fail_primary)
    }, |&(routers, fail_primary)| {
        let mut net = Network::new();
        let mut ov = Overlay::new(Cipher::Aes128Gcm);
        let l0 = net.add_location("c0");
        let l1 = net.add_location("c1");
        ov.add_central_point("cp0", l0, 0x0A000000, SimTime(0.0))
            .map_err(|e| e.to_string())?;
        ov.add_central_point("cp1", l1, 0x0A000100, SimTime(0.0))
            .map_err(|e| e.to_string())?;
        let mut names = Vec::new();
        for i in 0..routers {
            let loc = net.add_location(&format!("s{i}"));
            let n = format!("vr{i}");
            ov.add_site_router(&n, loc, 0x0A010000 + ((i as u32) << 8),
                               SimTime(1.0))
                .map_err(|e| e.to_string())?;
            names.push(n);
        }
        let victim = if fail_primary { "cp0" } else { "cp1" };
        ov.fail_central_point(victim, SimTime(10.0))
            .map_err(|e| e.to_string())?;
        for a in &names {
            for b in &names {
                if !ov.is_connected(a, b) {
                    return Err(format!(
                        "{a} !-> {b} after {victim} failure"));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Whole-cluster invariants across random scenarios
// ---------------------------------------------------------------------

#[test]
fn prop_cluster_scenarios_complete_and_respect_bounds() {
    check_n("cluster-scenarios", 12, |r: &mut Prng| {
        let scale = r.uniform(0.01, 0.08);
        let seed = r.next_u64();
        let serialized = r.chance(0.5);
        let max_workers = 2 + r.next_below(5) as u32;
        (scale, seed, serialized, max_workers)
    }, |&(scale, seed, serialized, max_workers)| {
        let mut cfg = evhc::cluster::RunConfig::paper_usecase(scale, seed);
        cfg.serialized_orchestrator = serialized;
        cfg.template.scalable.max_instances = max_workers;
        cfg.template.scalable.count =
            cfg.template.scalable.count.min(max_workers);
        let total = cfg.workload.total_jobs();
        let report = evhc::cluster::HybridCluster::new(cfg)
            .map_err(|e| e.to_string())?
            .run()
            .map_err(|e| e.to_string())?;
        if report.jobs_completed != total {
            return Err(format!("{}/{total} jobs", report.jobs_completed));
        }
        // Worker-count bound: count concurrent worker incarnations from
        // the recorder (PoweringOn..Off window) at each transition point.
        let mut alive = std::collections::HashSet::new();
        for (_, node, s) in &report.recorder.transitions_named() {
            if !node.starts_with("vnode-") {
                continue;
            }
            use evhc::metrics::DisplayState as D;
            match s {
                D::PoweringOn | D::Idle | D::Used | D::PoweringOff
                | D::Failed => {
                    alive.insert(node.clone());
                }
                D::Off => {
                    alive.remove(node);
                }
            }
            if alive.len() as u32 > max_workers {
                return Err(format!(
                    "{} workers alive > max {max_workers}", alive.len()));
            }
        }
        Ok(())
    });
}
