//! Integration test: the paper's §4 use case end to end (FIG8 topology,
//! the burst, the staircase, the failure episode, cost/utilization
//! shape). Runs at full scale — the DES replays 5h40m in milliseconds.

use evhc::cloudsim::{InjectionPlan, TransientDown};
use evhc::cluster::{HybridCluster, RunConfig, RunReport};
use evhc::im::NodeRole;
use evhc::metrics::DisplayState;
use evhc::sim::SimTime;

fn paper_run(seed: u64) -> RunReport {
    let mut cfg = RunConfig::paper_usecase(1.0, seed);
    cfg.injections = InjectionPlan {
        transient_downs: vec![TransientDown {
            node_name: "vnode-5".into(),
            start: SimTime(4800.0),
            duration_secs: 300.0,
        }],
    };
    HybridCluster::new(cfg).unwrap().run().unwrap()
}

#[test]
fn fig8_topology_realized() {
    let report = paper_run(42);
    // FE at CESNET with the deployment's only public-IP role; workers at
    // both sites; exactly one vRouter VM, at AWS.
    let fe: Vec<_> = report.per_vm.iter()
        .filter(|r| r.role == NodeRole::FrontEnd).collect();
    assert_eq!(fe.len(), 1);
    assert_eq!(fe[0].site, "CESNET-MCC");
    let vrouters: Vec<_> = report.per_vm.iter()
        .filter(|r| r.role == NodeRole::SiteVRouter).collect();
    assert_eq!(vrouters.len(), 1, "{vrouters:?}");
    assert_eq!(vrouters[0].site, "AWS");
    assert!(report.per_vm.iter().any(|r| r.role == NodeRole::WorkerNode
        && r.site == "CESNET-MCC"));
    assert!(report.per_vm.iter().any(|r| r.role == NodeRole::WorkerNode
        && r.site == "AWS"));
}

#[test]
fn full_workload_completes_with_paper_shape() {
    let report = paper_run(42);
    assert_eq!(report.jobs_completed, 3676);

    // Makespan within ±25% of the paper's 5h40m.
    let hours = report.makespan.0 / 3600.0;
    assert!((4.2..7.2).contains(&hours), "makespan {hours:.2} h");

    // Cost magnitude ~ $0.75.
    assert!((0.3..1.5).contains(&report.total_cost_usd),
            "cost {}", report.total_cost_usd);

    // Paid utilization in the 50-90% band around the paper's 66%.
    let util = report.paid_utilization();
    assert!((0.5..0.9).contains(&util), "util {util}");

    // AWS worker busy hours ~ the paper's 9.7 h.
    let aws_busy: f64 = report.per_vm.iter()
        .filter(|r| r.site == "AWS" && r.role == NodeRole::WorkerNode)
        .map(|r| r.busy_hours)
        .sum();
    assert!((6.0..13.0).contains(&aws_busy), "AWS busy {aws_busy:.2} h");
}

#[test]
fn aws_deploys_take_about_twenty_minutes() {
    let report = paper_run(42);
    let deploys: Vec<f64> = report.deploy_times.iter()
        .filter(|(n, _, _)| n.starts_with("vnode-"))
        .map(|(_, r, j)| (j.0 - r.0) / 60.0)
        .collect();
    assert!(!deploys.is_empty());
    let mean = evhc::util::stats::mean(&deploys);
    assert!((14.0..26.0).contains(&mean),
            "mean deploy {mean:.1} min (paper ~19-20)");
}

#[test]
fn vnode5_failure_and_poweroff_cancellation_episodes() {
    let report = paper_run(42);
    let trans = report.recorder.transitions_named();
    assert!(trans.iter().any(|(_, n, s)|
        n == "vnode-5" && *s == DisplayState::Failed),
        "vnode-5 must be marked failed");
    // Replacement after the failure (jobs remained).
    let failed_at = trans.iter()
        .find(|(_, n, s)| n == "vnode-5" && *s == DisplayState::Failed)
        .map(|(t, _, _)| t.0)
        .unwrap();
    assert!(trans.iter().any(|(t, n, s)|
        t.0 > failed_at && n.starts_with("vnode-")
        && *s == DisplayState::PoweringOn),
        "a replacement must be powered on after the failure");
    // At least one pending power-off was cancelled by early job arrival.
    assert!(report.recorder.milestones.iter().any(|(_, m)|
        m.contains("cancelled")), "cancellation episode missing");
}

#[test]
fn deterministic_across_identical_seeds() {
    let a = paper_run(7);
    let b = paper_run(7);
    assert_eq!(a.jobs_completed, b.jobs_completed);
    assert_eq!(a.makespan.0, b.makespan.0);
    assert_eq!(a.total_cost_usd, b.total_cost_usd);
    assert_eq!(a.recorder.transitions.len(),
               b.recorder.transitions.len());
}

#[test]
fn seeds_vary_but_shape_holds() {
    for seed in [1, 99, 12345] {
        let r = paper_run(seed);
        assert_eq!(r.jobs_completed, 3676, "seed {seed}");
        let hours = r.makespan.0 / 3600.0;
        assert!((4.0..8.0).contains(&hours),
                "seed {seed}: makespan {hours:.2}");
        assert!(r.total_cost_usd < 2.0, "seed {seed}");
    }
}

#[test]
fn htcondor_template_runs_the_same_scenario() {
    let mut cfg = RunConfig::paper_usecase(0.1, 5);
    cfg.template = evhc::tosca::builtin("htcondor").unwrap();
    let total = cfg.workload.total_jobs();
    let report = HybridCluster::new(cfg).unwrap().run().unwrap();
    assert_eq!(report.jobs_completed, total);
}

#[test]
fn three_site_federation_spreads_load() {
    let mut cfg = RunConfig::paper_usecase(0.3, 11);
    cfg.sites.push(evhc::cloudsim::SiteSpec::opennebula("INFN-BARI"));
    cfg.slas.push(evhc::orchestrator::Sla {
        site_name: "INFN-BARI".into(),
        priority: 1, // same priority as AWS
        max_instances: Some(2),
    });
    // Prefer the free academic site over AWS for the burst.
    cfg.slas.iter_mut().find(|s| s.site_name == "AWS").unwrap().priority =
        2;
    cfg.template.scalable.max_instances = 7;
    let report = HybridCluster::new(cfg).unwrap().run().unwrap();
    // Burst must hit INFN-BARI first (higher priority than AWS).
    assert!(report.per_vm.iter().any(|r| r.site == "INFN-BARI"
        && r.role == NodeRole::WorkerNode), "{:?}",
        report.per_vm.iter().map(|r| (&r.name, &r.site))
            .collect::<Vec<_>>());
    // And a vRouter was provisioned there too.
    assert!(report.per_vm.iter().any(|r| r.site == "INFN-BARI"
        && r.role == NodeRole::SiteVRouter));
}

#[test]
fn stochastic_vm_crashes_are_absorbed() {
    // Aggressive crash rate at AWS: ~1 crash per VM-hour. The elasticity
    // loop must keep replacing nodes until the workload completes.
    let mut cfg = RunConfig::paper_usecase(0.1, 21);
    cfg.sites[1].failure.crash_rate_per_hour = 1.0;
    let total = cfg.workload.total_jobs();
    let report = HybridCluster::new(cfg).unwrap().run().unwrap();
    assert_eq!(report.jobs_completed, total);
    // At least one crash actually happened at this rate/seed.
    let crashes = report.recorder.milestones.iter()
        .filter(|(_, m)| m.contains("crashed"))
        .count();
    assert!(crashes > 0, "expected crashes with rate 1.0/h");
}

#[test]
fn boot_failures_are_retried() {
    let mut cfg = RunConfig::paper_usecase(0.05, 33);
    cfg.sites[1].failure.boot_failure_prob = 0.4;
    let total = cfg.workload.total_jobs();
    let report = HybridCluster::new(cfg).unwrap().run().unwrap();
    assert_eq!(report.jobs_completed, total);
}
