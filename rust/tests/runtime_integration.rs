//! PJRT runtime integration: artifact loading, golden cross-check against
//! the JAX build path, batching semantics, and the use case with real
//! inference on the request path.
//!
//! These tests need `make artifacts` to have run; they skip (pass
//! trivially, with a note) when artifacts are missing so plain
//! `cargo test` works on a fresh checkout.

use evhc::runtime::{artifacts_available, read_manifest, ModelRuntime};
use evhc::workload::{synth_clip, N_CLASSES};

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn manifest_lists_both_batch_sizes() {
    require_artifacts!();
    let entries = read_manifest(std::path::Path::new("artifacts")).unwrap();
    let batches: Vec<usize> = entries.iter().map(|e| e.batch).collect();
    assert!(batches.contains(&1) && batches.contains(&8), "{batches:?}");
    for e in &entries {
        assert_eq!(e.n_classes, N_CLASSES);
        assert!(e.param_count > 500_000);
    }
}

#[test]
fn golden_logit_matches_jax_build_path() {
    require_artifacts!();
    let rt = ModelRuntime::load("artifacts", 1).unwrap();
    let err = rt.verify_golden().unwrap();
    assert!(err < 1e-3, "|Δ|={err}");
}

#[test]
fn batch8_matches_batch1_per_clip() {
    require_artifacts!();
    let rt1 = ModelRuntime::load("artifacts", 1).unwrap();
    let rt8 = ModelRuntime::load("artifacts", 8).unwrap();
    let clips: Vec<Vec<f32>> = (0..8).map(|i| synth_clip(i)).collect();
    let batched = rt8.infer(&clips).unwrap();
    for (i, clip) in clips.iter().enumerate() {
        let single = rt1.infer(std::slice::from_ref(clip)).unwrap();
        let max_diff = batched[i]
            .iter()
            .zip(&single[0])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "clip {i}: max diff {max_diff}");
    }
}

#[test]
fn partial_batches_are_padded_and_sliced() {
    require_artifacts!();
    let rt8 = ModelRuntime::load("artifacts", 8).unwrap();
    let clips: Vec<Vec<f32>> = (0..3).map(|i| synth_clip(100 + i)).collect();
    let out = rt8.infer(&clips).unwrap();
    assert_eq!(out.len(), 3);
    assert!(out.iter().all(|l| l.len() == N_CLASSES));
    // Oversized batches are rejected.
    let too_many: Vec<Vec<f32>> = (0..9).map(|i| synth_clip(i)).collect();
    assert!(rt8.infer(&too_many).is_err());
    // Wrong clip length is rejected.
    assert!(rt8.infer(&[vec![0.0; 7]]).is_err());
}

#[test]
fn different_files_give_different_predictions() {
    require_artifacts!();
    let rt = ModelRuntime::load("artifacts", 1).unwrap();
    let a = rt.infer_file(1).unwrap();
    let b = rt.infer_file(2).unwrap();
    let top_a = ModelRuntime::top_k(&a, 1)[0].0;
    let top_b = ModelRuntime::top_k(&b, 1)[0].0;
    // Logits must differ substantially even if argmax collides.
    let max_diff = a.iter().zip(&b).map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff > 0.1, "top_a={top_a} top_b={top_b}");
}

#[test]
fn usecase_with_real_inference_on_request_path() {
    require_artifacts!();
    let mut cfg = evhc::cluster::RunConfig::paper_usecase(0.02, 3);
    cfg.inference_every = 5; // every 5th job runs the real model
    let total = cfg.workload.total_jobs();
    let report = evhc::cluster::HybridCluster::new(cfg).unwrap()
        .run().unwrap();
    assert_eq!(report.jobs_completed, total);
    assert!(report.inferences_run >= (total / 5) as u64,
            "{} inferences for {total} jobs", report.inferences_run);
    assert!(report.inference_wall_secs > 0.0);
}
