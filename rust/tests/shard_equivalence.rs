//! The sharded engine's parallel windowed replay — chunked *and*
//! work-stealing — must be byte-for-byte equivalent to the single-queue
//! (serial deterministic merge) replay: same per-shard dispatch order,
//! same control-plane event stream, and byte-identical figure outputs
//! from the merged per-shard recorders — on randomized multi-site
//! scenarios, including skew-heavy worlds (one hot site carrying up to
//! 32× the jobs of a cold site, the regime work stealing exists for).
//! The streaming spill merge must reproduce `Recorder::merge_shards`
//! byte-for-byte. Plus model-checked EventQueue generation-slot
//! cancellation invariants under randomized schedule/cancel/pop
//! interleavings.
//!
//! `EVHC_PROPTEST_CASES` bounds every property's case count (the CI
//! quick mode sets it low; unset, each property uses its own default).

use evhc::ids::NodeNames;
use evhc::lrms::core::{BatchCore, Placement};
use evhc::lrms::JobId;
use evhc::metrics::{DisplayState, Recorder, ShardSink, SpillFiles};
use evhc::sim::shard::{run_sharded, run_sharded_serial,
                       run_sharded_stealing, ControlPlane, SiteCtx,
                       SiteShard, StealConfig};
use evhc::sim::{EventQueue, ShardEvent, ShardKey, ShardedQueue, SimTime};
use evhc::util::prng::Prng;
use evhc::util::proptest::check_n;

/// Per-property case budget, bounded by `EVHC_PROPTEST_CASES` when set
/// (the CI quick mode caps the skew-heavy properties this way).
fn cases(default: u32) -> u32 {
    std::env::var("EVHC_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

// ---------------------------------------------------------------------
// Randomized sharded world: per-site LRMS core + recorder, control
// fan-out blocks (optionally skewed towards hot site 0), site→control
// progress reports.
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
enum PEv {
    /// Control: fan one submission block out to every site.
    Block { per_site: u32 },
    /// Control: progress report emitted by a site shard.
    Progress { site: u32, done: u32 },
    /// Site: submit `n` jobs.
    Submit { site: u32, n: u32 },
    /// Site: a job finished.
    Done { site: u32, job: JobId },
}

impl ShardEvent for PEv {
    fn shard_key(&self) -> ShardKey {
        match self {
            PEv::Block { .. } | PEv::Progress { .. } => ShardKey::Control,
            PEv::Submit { site, .. } | PEv::Done { site, .. } => {
                ShardKey::Site(*site)
            }
        }
    }
}

struct PropSite {
    site: u32,
    core: BatchCore,
    rec: Recorder,
    rng: Prng,
    completed: u32,
    report_every: u32,
    lookahead: f64,
    /// Per-shard dispatch log: (time bits, tag).
    log: Vec<(u64, u32)>,
}

impl PropSite {
    fn record_assignments(&mut self, t: SimTime,
                          assigned: &[(JobId, evhc::ids::NodeId)],
                          ctx: &mut SiteCtx<'_, PEv>) {
        for &(job, node) in assigned {
            let name = self.core.node_name(node).expect("assigned node");
            self.rec.node_state(t, &name, DisplayState::Used);
            let dur = 5.0 + self.rng.next_f64() * 20.0;
            ctx.schedule_in(dur, PEv::Done { site: self.site, job });
        }
    }
}

impl SiteShard for PropSite {
    type Event = PEv;

    fn handle(&mut self, t: SimTime, ev: PEv, ctx: &mut SiteCtx<'_, PEv>) {
        match ev {
            PEv::Submit { n, .. } => {
                self.log.push((t.0.to_bits(), 1_000_000 + n));
                for i in 0..n {
                    self.core.submit("", 1 + (i % 2), t);
                }
            }
            PEv::Done { job, .. } => {
                self.log.push((t.0.to_bits(), job.0 as u32));
                let _ = self.core.on_job_finished(job, true, t);
                self.completed += 1;
                if let Some(j) = self.core.job(job) {
                    if let (Some(node), Some(s), Some(e)) =
                        (j.node, j.started_at, j.finished_at)
                    {
                        let name = self
                            .core
                            .node_name(node)
                            .expect("node still registered");
                        self.rec.job_run(&name, s, e);
                        if self
                            .core
                            .node_stat(node)
                            .map(|st| st.used_slots == 0)
                            .unwrap_or(false)
                        {
                            self.rec.node_state(t, &name,
                                                DisplayState::Idle);
                        }
                    }
                }
                if self.completed % self.report_every == 0 {
                    ctx.emit_control_in(self.lookahead, PEv::Progress {
                        site: self.site,
                        done: self.completed,
                    });
                }
            }
            _ => unreachable!("control event in site shard"),
        }
        let assigned = self.core.schedule(t);
        self.record_assignments(t, &assigned, ctx);
    }
}

struct PropControl {
    sites_n: u32,
    /// Hot-site multiplier: site 0 receives `hot`× the block jobs of
    /// each cold site (1 = uniform world).
    hot: u32,
    lookahead: f64,
    /// Control dispatch log: (time bits, site-or-MAX, payload).
    log: Vec<(u64, u32, u32)>,
}

impl ControlPlane for PropControl {
    type Site = PropSite;

    fn handle(&mut self, _sites: &mut [PropSite], t: SimTime, ev: PEv,
              q: &mut ShardedQueue<PEv>) {
        match ev {
            PEv::Block { per_site } => {
                self.log.push((t.0.to_bits(), u32::MAX, per_site));
                for s in 0..self.sites_n {
                    let n = if s == 0 {
                        per_site * self.hot
                    } else {
                        per_site
                    };
                    q.schedule_at(t, PEv::Submit { site: s, n });
                }
            }
            PEv::Progress { site, done } => {
                self.log.push((t.0.to_bits(), site, done));
            }
            _ => unreachable!("site event in control shard"),
        }
    }

    fn lookahead(&self) -> f64 {
        self.lookahead
    }
}

#[derive(Debug, Clone)]
struct Scn {
    sites: u32,
    nodes_per_site: u32,
    slots: u32,
    jobs_per_block: u32,
    blocks: u32,
    /// Hot-site multiplier (1 = uniform).
    hot: u32,
    lookahead: f64,
    report_every: u32,
    threads: usize,
    seed: u64,
}

impl Scn {
    fn total_jobs(&self) -> u32 {
        (self.sites - 1 + self.hot) * self.jobs_per_block * self.blocks
    }

    fn steal_cfg(&self) -> StealConfig {
        StealConfig::new(self.threads)
    }
}

fn gen_scn(r: &mut Prng) -> Scn {
    Scn {
        sites: 2 + r.next_below(3) as u32,
        nodes_per_site: 1 + r.next_below(3) as u32,
        slots: 1 + r.next_below(2) as u32,
        jobs_per_block: 2 + r.next_below(20) as u32,
        blocks: 1 + r.next_below(3) as u32,
        hot: 1,
        lookahead: if r.chance(0.5) { 3.0 } else { 47.0 },
        report_every: 1 + r.next_below(4) as u32,
        threads: 2 + r.next_below(3) as usize,
        seed: r.next_u64(),
    }
}

/// Skew-heavy worlds: one hot site + 2–5 cold sites, the hot site
/// carrying 8–32× the jobs — the regime where the chunked engine
/// serializes and work stealing must not change a single byte.
fn gen_skew(r: &mut Prng) -> Scn {
    Scn {
        sites: 3 + r.next_below(4) as u32,
        nodes_per_site: 1 + r.next_below(3) as u32,
        slots: 1 + r.next_below(2) as u32,
        jobs_per_block: 2 + r.next_below(8) as u32,
        blocks: 1 + r.next_below(3) as u32,
        hot: 8 + r.next_below(25) as u32,
        lookahead: if r.chance(0.5) { 3.0 } else { 47.0 },
        report_every: 1 + r.next_below(4) as u32,
        threads: 2 + r.next_below(3) as usize,
        seed: r.next_u64(),
    }
}

fn build(scn: &Scn) -> (PropControl, Vec<PropSite>, ShardedQueue<PEv>) {
    let mut sites = Vec::new();
    for s in 0..scn.sites {
        let mut core = BatchCore::new(Placement::PackFirstFit);
        for k in 0..scn.nodes_per_site {
            core.register_node(&format!("s{s}-n{k}"), scn.slots,
                               SimTime(0.0));
        }
        sites.push(PropSite {
            site: s,
            core,
            rec: Recorder::new(),
            rng: Prng::new(scn.seed ^ (s as u64 + 1)
                .wrapping_mul(0x9E3779B97F4A7C15)),
            completed: 0,
            report_every: scn.report_every,
            lookahead: scn.lookahead,
            log: Vec::new(),
        });
    }
    let mut q: ShardedQueue<PEv> = ShardedQueue::new(scn.sites as usize);
    for b in 0..scn.blocks {
        q.schedule_at(SimTime(b as f64 * 50.0), PEv::Block {
            per_site: scn.jobs_per_block,
        });
    }
    (PropControl {
        sites_n: scn.sites,
        hot: scn.hot,
        lookahead: scn.lookahead,
        log: Vec::new(),
    }, sites, q)
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Engine {
    Serial,
    Parallel,
    Stealing,
}

/// Everything observable about a finished run, figures included.
struct Outcome {
    control_log: Vec<(u64, u32, u32)>,
    site_logs: Vec<Vec<(u64, u32)>>,
    completed: Vec<u32>,
    dispatched: u64,
    transitions: Vec<(SimTime, String, DisplayState)>,
    milestones: Vec<(SimTime, String)>,
    fig10: String,
    fig11: String,
}

fn run(scn: &Scn, engine: Engine) -> Outcome {
    let (mut control, mut sites, mut q) = build(scn);
    match engine {
        Engine::Serial => {
            run_sharded_serial(&mut control, &mut sites, &mut q,
                               SimTime(f64::INFINITY));
        }
        Engine::Parallel => {
            run_sharded(&mut control, &mut sites, &mut q,
                        SimTime(f64::INFINITY), scn.threads);
        }
        Engine::Stealing => {
            run_sharded_stealing(&mut control, &mut sites, &mut q,
                                 SimTime(f64::INFINITY), scn.steal_cfg());
        }
    }
    let dispatched = q.dispatched();
    let completed = sites.iter().map(|s| s.completed).collect();
    let site_logs = sites.iter().map(|s| s.log.clone()).collect();
    let control_log = control.log.clone();
    let recs: Vec<Recorder> = sites.into_iter().map(|s| s.rec).collect();
    let merged = Recorder::merge_shards(NodeNames::new(), &recs);
    Outcome {
        control_log,
        site_logs,
        completed,
        dispatched,
        transitions: merged.transitions_named(),
        milestones: merged.milestones.clone(),
        fig10: merged.fig10_usage(25.0, SimTime(600.0)).to_csv(),
        fig11: merged.fig11_states(25.0, SimTime(600.0)).to_csv(),
    }
}

/// Byte-level comparison of two outcomes; `what` names the pairing in
/// failure messages.
fn diff(a: &Outcome, b: &Outcome, what: &str) -> Result<(), String> {
    if a.control_log != b.control_log {
        return Err(format!(
            "{what}: control stream diverged:\n  left:  {:?}\n  \
             right: {:?}", a.control_log, b.control_log));
    }
    if a.site_logs != b.site_logs {
        return Err(format!("{what}: per-shard dispatch order diverged"));
    }
    if a.completed != b.completed {
        return Err(format!("{what}: completions diverged: {:?} vs {:?}",
                           a.completed, b.completed));
    }
    if a.dispatched != b.dispatched {
        return Err(format!("{what}: dispatch counts diverged: {} vs {}",
                           a.dispatched, b.dispatched));
    }
    if a.transitions != b.transitions {
        return Err(format!("{what}: merged transition streams diverged"));
    }
    if a.milestones != b.milestones {
        return Err(format!("{what}: merged milestones diverged"));
    }
    if a.fig10 != b.fig10 {
        return Err(format!("{what}: fig10 output not byte-identical"));
    }
    if a.fig11 != b.fig11 {
        return Err(format!("{what}: fig11 output not byte-identical"));
    }
    Ok(())
}

#[test]
fn prop_parallel_sharded_replay_equals_single_queue() {
    check_n("sharded-eq-single-queue", cases(48), gen_scn, |scn| {
        let a = run(scn, Engine::Serial);
        let b = run(scn, Engine::Parallel);
        let c = run(scn, Engine::Stealing);
        diff(&a, &b, "serial-vs-parallel")?;
        diff(&a, &c, "serial-vs-stealing")?;
        // Sanity: the scenario did real work.
        let total: u32 = a.completed.iter().sum();
        if total != scn.total_jobs() {
            return Err(format!("workload not drained: {total}"));
        }
        Ok(())
    });
}

/// Skew-heavy property suite: 1 hot site + N cold sites, stealing on
/// and off, merged recorders byte-compared against the single-queue
/// reference.
#[test]
fn prop_stealing_equals_single_queue_on_skewed_worlds() {
    check_n("stealing-eq-skew", cases(32), gen_skew, |scn| {
        let a = run(scn, Engine::Serial);
        let b = run(scn, Engine::Parallel);
        let c = run(scn, Engine::Stealing);
        diff(&a, &b, "skew-serial-vs-parallel")?;
        diff(&a, &c, "skew-serial-vs-stealing")?;
        let total: u32 = a.completed.iter().sum();
        if total != scn.total_jobs() {
            return Err(format!("workload not drained: {total}"));
        }
        // The hot shard really is hot: it completed more than any cold
        // shard (otherwise the generator stopped generating skew).
        let hot = a.completed[0];
        if a.completed[1..].iter().any(|&c| c >= hot) {
            return Err(format!("skew lost: {:?}", a.completed));
        }
        Ok(())
    });
}

/// Two parallel replays (same seed) must also agree with each other —
/// thread scheduling must not leak into any observable stream.
#[test]
fn prop_parallel_replay_is_internally_deterministic() {
    check_n("sharded-parallel-deterministic", cases(16), gen_scn, |scn| {
        let a = run(scn, Engine::Parallel);
        let b = run(scn, Engine::Parallel);
        diff(&a, &b, "parallel-rerun")
    });
}

/// Same for the work-stealing engine, on skewed worlds: whichever
/// worker steals whichever segment, the streams must not move.
#[test]
fn prop_stealing_replay_is_internally_deterministic() {
    check_n("stealing-deterministic", cases(12), gen_skew, |scn| {
        let a = run(scn, Engine::Stealing);
        let b = run(scn, Engine::Stealing);
        diff(&a, &b, "stealing-rerun")
    });
}

// ---------------------------------------------------------------------
// Recorder::merge_shards vs the streaming spill merge.
// ---------------------------------------------------------------------

/// The streaming k-way spill merge must reproduce the in-memory
/// `merge_shards` byte-for-byte, down to fig10/fig11 CSV output.
#[test]
fn prop_merge_shards_equals_streaming_spill_merge() {
    check_n("merge-shards-eq-spill", cases(24), gen_scn, |scn| {
        let (mut control, mut sites, mut q) = build(scn);
        run_sharded_serial(&mut control, &mut sites, &mut q,
                           SimTime(f64::INFINITY));
        let recs: Vec<Recorder> =
            sites.into_iter().map(|s| s.rec).collect();
        let dir = std::env::temp_dir()
            .join(format!("evhc_spill_eqprop_{:016x}", scn.seed));
        let _ = std::fs::remove_dir_all(&dir);
        let spills: Vec<SpillFiles> = recs
            .iter()
            .enumerate()
            .map(|(i, r)| r.spill_to(&dir, i as u32).expect("spill_to"))
            .collect();
        let mem = Recorder::merge_shards(NodeNames::new(), &recs);
        let streamed = Recorder::merge_spills(NodeNames::new(), &spills)
            .map_err(|e| format!("merge_spills: {e}"))?;
        let _ = std::fs::remove_dir_all(&dir);
        if mem.transitions_named() != streamed.transitions_named() {
            return Err("spill merge: transitions diverged".into());
        }
        if mem.milestones != streamed.milestones {
            return Err("spill merge: milestones diverged".into());
        }
        if mem.node_names() != streamed.node_names() {
            return Err("spill merge: node order diverged".into());
        }
        let until = SimTime(600.0);
        if mem.fig10_usage(25.0, until).to_csv()
            != streamed.fig10_usage(25.0, until).to_csv()
        {
            return Err("spill merge: fig10 not byte-identical".into());
        }
        if mem.fig11_states(25.0, until).to_csv()
            != streamed.fig11_states(25.0, until).to_csv()
        {
            return Err("spill merge: fig11 not byte-identical".into());
        }
        Ok(())
    });
}

/// Spill-mode recorders *during* a work-stealing replay (each shard
/// streaming from its worker thread) must merge to the same bytes as
/// in-memory recorders during a serial replay.
#[test]
fn live_spill_recorders_match_in_memory_merge() {
    let mut r = Prng::new(0xFEED);
    let scn = gen_skew(&mut r);

    let (mut c1, mut s1, mut q1) = build(&scn);
    run_sharded_serial(&mut c1, &mut s1, &mut q1, SimTime(f64::INFINITY));
    let recs: Vec<Recorder> = s1.into_iter().map(|s| s.rec).collect();
    let mem = Recorder::merge_shards(NodeNames::new(), &recs);

    let dir = std::env::temp_dir().join("evhc_spill_live_test");
    let _ = std::fs::remove_dir_all(&dir);
    let (mut c2, mut s2, mut q2) = build(&scn);
    for (i, site) in s2.iter_mut().enumerate() {
        site.rec = Recorder::with_spill(
            NodeNames::new(),
            ShardSink::create(&dir, i as u32).expect("sink"),
        );
    }
    run_sharded_stealing(&mut c2, &mut s2, &mut q2,
                         SimTime(f64::INFINITY), scn.steal_cfg());
    let files: Vec<SpillFiles> = s2
        .into_iter()
        .map(|mut s| {
            s.rec.finish_spill().expect("spilling").expect("spill io")
        })
        .collect();
    assert!(files.iter().all(|f| f.bytes > 0), "spills were written");
    let streamed =
        Recorder::merge_spills(NodeNames::new(), &files).expect("merge");
    let _ = std::fs::remove_dir_all(&dir);

    assert_eq!(mem.transitions_named(), streamed.transitions_named());
    assert_eq!(mem.milestones, streamed.milestones);
    assert_eq!(mem.node_names(), streamed.node_names());
    let until = SimTime(600.0);
    assert_eq!(mem.fig10_usage(25.0, until).to_csv(),
               streamed.fig10_usage(25.0, until).to_csv());
    assert_eq!(mem.fig11_states(25.0, until).to_csv(),
               streamed.fig11_states(25.0, until).to_csv());
}

// ---------------------------------------------------------------------
// EventQueue generation-slot cancellation: model-checked invariants.
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
enum MState {
    Live,
    Cancelled,
    Fired,
}

#[test]
fn prop_event_queue_cancellation_model() {
    check_n("event-queue-cancel-model", cases(96), |r: &mut Prng| {
        let n = 20 + r.next_below(200) as usize;
        (0..n).map(|_| r.next_u64()).collect::<Vec<u64>>()
    }, |ops| {
        let mut q: EventQueue<usize> = EventQueue::new();
        // Model: (effective time, value, state), insertion-ordered.
        let mut model: Vec<(f64, usize, MState)> = Vec::new();
        let mut handles = Vec::new();
        let mut now = 0.0f64;
        for &op in ops {
            match op % 4 {
                0 | 1 => {
                    let t = ((op >> 8) % 1000) as f64 / 10.0;
                    let v = model.len();
                    handles.push(q.schedule_at(SimTime(t), v));
                    model.push((t.max(now), v, MState::Live));
                }
                2 => {
                    if handles.is_empty() {
                        continue;
                    }
                    let k = ((op >> 8) as usize) % handles.len();
                    let expected = model[k].2 == MState::Live;
                    let got = q.cancel(handles[k]);
                    if got != expected {
                        return Err(format!(
                            "cancel #{k}: got {got}, expected {expected} \
                             (state {:?})", model[k].2));
                    }
                    if expected {
                        model[k].2 = MState::Cancelled;
                    }
                    // Idempotence: a second cancel must always fail.
                    if q.cancel(handles[k]) {
                        return Err(format!("double-cancel #{k} succeeded"));
                    }
                }
                _ => {
                    // Model pop: live entry with min (time, insertion).
                    let next = model
                        .iter()
                        .enumerate()
                        .filter(|(_, e)| e.2 == MState::Live)
                        .min_by(|(_, x), (_, y)| {
                            x.0.total_cmp(&y.0)
                        })
                        .map(|(i, e)| (i, e.0, e.1));
                    match (q.pop(), next) {
                        (None, None) => {}
                        (Some((t, v)), Some((i, mt, mv))) => {
                            if v != mv || t.0 != mt {
                                return Err(format!(
                                    "pop mismatch: got ({}, {v}), \
                                     model ({mt}, {mv})", t.0));
                            }
                            if t.0 < now {
                                return Err("time went backwards".into());
                            }
                            now = t.0;
                            model[i].2 = MState::Fired;
                        }
                        (got, want) => {
                            return Err(format!(
                                "pop disagreement: queue {got:?}, \
                                 model {want:?}"));
                        }
                    }
                }
            }
            let live = model.iter().filter(|e| e.2 == MState::Live).count();
            if q.live_count() != live {
                return Err(format!(
                    "live_count {} != model {live}", q.live_count()));
            }
        }
        // Drain: everything still live fires, in model order.
        while let Some((t, v)) = q.pop() {
            let next = model
                .iter()
                .enumerate()
                .filter(|(_, e)| e.2 == MState::Live)
                .min_by(|(_, x), (_, y)| x.0.total_cmp(&y.0))
                .map(|(i, e)| (i, e.1));
            match next {
                Some((i, mv)) if mv == v => model[i].2 = MState::Fired,
                other => {
                    return Err(format!(
                        "drain pop ({}, {v}) but model says {other:?}",
                        t.0));
                }
            }
        }
        if model.iter().any(|e| e.2 == MState::Live) {
            return Err("live events lost at drain".into());
        }
        Ok(())
    });
}
