//! The sharded engine's parallel windowed replay must be byte-for-byte
//! equivalent to the single-queue (serial deterministic merge) replay:
//! same per-shard dispatch order, same control-plane event stream, and
//! byte-identical figure outputs from the merged per-shard recorders —
//! on randomized multi-site scenarios. Plus model-checked EventQueue
//! generation-slot cancellation invariants under randomized
//! schedule/cancel/pop interleavings.

use evhc::ids::NodeNames;
use evhc::lrms::core::{BatchCore, Placement};
use evhc::lrms::JobId;
use evhc::metrics::{DisplayState, Recorder};
use evhc::sim::shard::{run_sharded, run_sharded_serial, ControlPlane,
                       SiteCtx, SiteShard};
use evhc::sim::{EventQueue, ShardEvent, ShardKey, ShardedQueue, SimTime};
use evhc::util::prng::Prng;
use evhc::util::proptest::check_n;

// ---------------------------------------------------------------------
// Randomized sharded world: per-site LRMS core + recorder, control
// fan-out blocks, site→control progress reports.
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
enum PEv {
    /// Control: fan one submission block out to every site.
    Block { per_site: u32 },
    /// Control: progress report emitted by a site shard.
    Progress { site: u32, done: u32 },
    /// Site: submit `n` jobs.
    Submit { site: u32, n: u32 },
    /// Site: a job finished.
    Done { site: u32, job: JobId },
}

impl ShardEvent for PEv {
    fn shard_key(&self) -> ShardKey {
        match self {
            PEv::Block { .. } | PEv::Progress { .. } => ShardKey::Control,
            PEv::Submit { site, .. } | PEv::Done { site, .. } => {
                ShardKey::Site(*site)
            }
        }
    }
}

struct PropSite {
    site: u32,
    core: BatchCore,
    rec: Recorder,
    rng: Prng,
    completed: u32,
    report_every: u32,
    lookahead: f64,
    /// Per-shard dispatch log: (time bits, tag).
    log: Vec<(u64, u32)>,
}

impl PropSite {
    fn record_assignments(&mut self, t: SimTime,
                          assigned: &[(JobId, evhc::ids::NodeId)],
                          ctx: &mut SiteCtx<'_, PEv>) {
        for &(job, node) in assigned {
            let name = self.core.node_name(node).expect("assigned node");
            self.rec.node_state(t, &name, DisplayState::Used);
            let dur = 5.0 + self.rng.next_f64() * 20.0;
            ctx.schedule_in(dur, PEv::Done { site: self.site, job });
        }
    }
}

impl SiteShard for PropSite {
    type Event = PEv;

    fn handle(&mut self, t: SimTime, ev: PEv, ctx: &mut SiteCtx<'_, PEv>) {
        match ev {
            PEv::Submit { n, .. } => {
                self.log.push((t.0.to_bits(), 1_000_000 + n));
                for i in 0..n {
                    self.core.submit("", 1 + (i % 2), t);
                }
            }
            PEv::Done { job, .. } => {
                self.log.push((t.0.to_bits(), job.0 as u32));
                let _ = self.core.on_job_finished(job, true, t);
                self.completed += 1;
                if let Some(j) = self.core.job(job) {
                    if let (Some(node), Some(s), Some(e)) =
                        (j.node, j.started_at, j.finished_at)
                    {
                        let name = self
                            .core
                            .node_name(node)
                            .expect("node still registered");
                        self.rec.job_run(&name, s, e);
                        if self
                            .core
                            .node_stat(node)
                            .map(|st| st.used_slots == 0)
                            .unwrap_or(false)
                        {
                            self.rec.node_state(t, &name,
                                                DisplayState::Idle);
                        }
                    }
                }
                if self.completed % self.report_every == 0 {
                    ctx.emit_control_in(self.lookahead, PEv::Progress {
                        site: self.site,
                        done: self.completed,
                    });
                }
            }
            _ => unreachable!("control event in site shard"),
        }
        let assigned = self.core.schedule(t);
        self.record_assignments(t, &assigned, ctx);
    }
}

struct PropControl {
    sites_n: u32,
    lookahead: f64,
    /// Control dispatch log: (time bits, site-or-MAX, payload).
    log: Vec<(u64, u32, u32)>,
}

impl ControlPlane for PropControl {
    type Site = PropSite;

    fn handle(&mut self, _sites: &mut [PropSite], t: SimTime, ev: PEv,
              q: &mut ShardedQueue<PEv>) {
        match ev {
            PEv::Block { per_site } => {
                self.log.push((t.0.to_bits(), u32::MAX, per_site));
                for s in 0..self.sites_n {
                    q.schedule_at(t, PEv::Submit { site: s, n: per_site });
                }
            }
            PEv::Progress { site, done } => {
                self.log.push((t.0.to_bits(), site, done));
            }
            _ => unreachable!("site event in control shard"),
        }
    }

    fn lookahead(&self) -> f64 {
        self.lookahead
    }
}

#[derive(Debug, Clone)]
struct Scn {
    sites: u32,
    nodes_per_site: u32,
    slots: u32,
    jobs_per_block: u32,
    blocks: u32,
    lookahead: f64,
    report_every: u32,
    threads: usize,
    seed: u64,
}

fn gen_scn(r: &mut Prng) -> Scn {
    Scn {
        sites: 2 + r.next_below(3) as u32,
        nodes_per_site: 1 + r.next_below(3) as u32,
        slots: 1 + r.next_below(2) as u32,
        jobs_per_block: 2 + r.next_below(20) as u32,
        blocks: 1 + r.next_below(3) as u32,
        lookahead: if r.chance(0.5) { 3.0 } else { 47.0 },
        report_every: 1 + r.next_below(4) as u32,
        threads: 2 + r.next_below(3) as usize,
        seed: r.next_u64(),
    }
}

fn build(scn: &Scn) -> (PropControl, Vec<PropSite>, ShardedQueue<PEv>) {
    let mut sites = Vec::new();
    for s in 0..scn.sites {
        let mut core = BatchCore::new(Placement::PackFirstFit);
        for k in 0..scn.nodes_per_site {
            core.register_node(&format!("s{s}-n{k}"), scn.slots,
                               SimTime(0.0));
        }
        sites.push(PropSite {
            site: s,
            core,
            rec: Recorder::new(),
            rng: Prng::new(scn.seed ^ (s as u64 + 1)
                .wrapping_mul(0x9E3779B97F4A7C15)),
            completed: 0,
            report_every: scn.report_every,
            lookahead: scn.lookahead,
            log: Vec::new(),
        });
    }
    let mut q: ShardedQueue<PEv> = ShardedQueue::new(scn.sites as usize);
    for b in 0..scn.blocks {
        q.schedule_at(SimTime(b as f64 * 50.0), PEv::Block {
            per_site: scn.jobs_per_block,
        });
    }
    (PropControl {
        sites_n: scn.sites,
        lookahead: scn.lookahead,
        log: Vec::new(),
    }, sites, q)
}

/// Everything observable about a finished run, figures included.
struct Outcome {
    control_log: Vec<(u64, u32, u32)>,
    site_logs: Vec<Vec<(u64, u32)>>,
    completed: Vec<u32>,
    dispatched: u64,
    transitions: Vec<(SimTime, String, DisplayState)>,
    milestones: Vec<(SimTime, String)>,
    fig10: String,
    fig11: String,
}

fn run(scn: &Scn, parallel: bool) -> Outcome {
    let (mut control, mut sites, mut q) = build(scn);
    if parallel {
        run_sharded(&mut control, &mut sites, &mut q,
                    SimTime(f64::INFINITY), scn.threads);
    } else {
        run_sharded_serial(&mut control, &mut sites, &mut q,
                           SimTime(f64::INFINITY));
    }
    let dispatched = q.dispatched();
    let completed = sites.iter().map(|s| s.completed).collect();
    let site_logs = sites.iter().map(|s| s.log.clone()).collect();
    let control_log = control.log.clone();
    let recs: Vec<Recorder> = sites.into_iter().map(|s| s.rec).collect();
    let merged = Recorder::merge_shards(NodeNames::new(), &recs);
    Outcome {
        control_log,
        site_logs,
        completed,
        dispatched,
        transitions: merged.transitions_named(),
        milestones: merged.milestones.clone(),
        fig10: merged.fig10_usage(25.0, SimTime(600.0)).to_csv(),
        fig11: merged.fig11_states(25.0, SimTime(600.0)).to_csv(),
    }
}

#[test]
fn prop_parallel_sharded_replay_equals_single_queue() {
    check_n("sharded-eq-single-queue", 48, gen_scn, |scn| {
        let a = run(scn, false);
        let b = run(scn, true);
        if a.control_log != b.control_log {
            return Err(format!(
                "control stream diverged:\n  serial:   {:?}\n  \
                 parallel: {:?}", a.control_log, b.control_log));
        }
        if a.site_logs != b.site_logs {
            return Err("per-shard dispatch order diverged".into());
        }
        if a.completed != b.completed {
            return Err(format!("completions diverged: {:?} vs {:?}",
                               a.completed, b.completed));
        }
        if a.dispatched != b.dispatched {
            return Err(format!("dispatch counts diverged: {} vs {}",
                               a.dispatched, b.dispatched));
        }
        if a.transitions != b.transitions {
            return Err("merged transition streams diverged".into());
        }
        if a.milestones != b.milestones {
            return Err("merged milestones diverged".into());
        }
        if a.fig10 != b.fig10 {
            return Err("fig10 output not byte-identical".into());
        }
        if a.fig11 != b.fig11 {
            return Err("fig11 output not byte-identical".into());
        }
        // Sanity: the scenario did real work.
        let total: u32 = a.completed.iter().sum();
        if total != scn.sites * scn.jobs_per_block * scn.blocks {
            return Err(format!("workload not drained: {total}"));
        }
        Ok(())
    });
}

/// Two parallel replays (same seed) must also agree with each other —
/// thread scheduling must not leak into any observable stream.
#[test]
fn prop_parallel_replay_is_internally_deterministic() {
    check_n("sharded-parallel-deterministic", 16, gen_scn, |scn| {
        let a = run(scn, true);
        let b = run(scn, true);
        if a.transitions != b.transitions || a.fig10 != b.fig10
            || a.control_log != b.control_log
        {
            return Err("parallel replay not deterministic".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// EventQueue generation-slot cancellation: model-checked invariants.
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
enum MState {
    Live,
    Cancelled,
    Fired,
}

#[test]
fn prop_event_queue_cancellation_model() {
    check_n("event-queue-cancel-model", 96, |r: &mut Prng| {
        let n = 20 + r.next_below(200) as usize;
        (0..n).map(|_| r.next_u64()).collect::<Vec<u64>>()
    }, |ops| {
        let mut q: EventQueue<usize> = EventQueue::new();
        // Model: (effective time, value, state), insertion-ordered.
        let mut model: Vec<(f64, usize, MState)> = Vec::new();
        let mut handles = Vec::new();
        let mut now = 0.0f64;
        for &op in ops {
            match op % 4 {
                0 | 1 => {
                    let t = ((op >> 8) % 1000) as f64 / 10.0;
                    let v = model.len();
                    handles.push(q.schedule_at(SimTime(t), v));
                    model.push((t.max(now), v, MState::Live));
                }
                2 => {
                    if handles.is_empty() {
                        continue;
                    }
                    let k = ((op >> 8) as usize) % handles.len();
                    let expected = model[k].2 == MState::Live;
                    let got = q.cancel(handles[k]);
                    if got != expected {
                        return Err(format!(
                            "cancel #{k}: got {got}, expected {expected} \
                             (state {:?})", model[k].2));
                    }
                    if expected {
                        model[k].2 = MState::Cancelled;
                    }
                    // Idempotence: a second cancel must always fail.
                    if q.cancel(handles[k]) {
                        return Err(format!("double-cancel #{k} succeeded"));
                    }
                }
                _ => {
                    // Model pop: live entry with min (time, insertion).
                    let next = model
                        .iter()
                        .enumerate()
                        .filter(|(_, e)| e.2 == MState::Live)
                        .min_by(|(_, x), (_, y)| {
                            x.0.total_cmp(&y.0)
                        })
                        .map(|(i, e)| (i, e.0, e.1));
                    match (q.pop(), next) {
                        (None, None) => {}
                        (Some((t, v)), Some((i, mt, mv))) => {
                            if v != mv || t.0 != mt {
                                return Err(format!(
                                    "pop mismatch: got ({}, {v}), \
                                     model ({mt}, {mv})", t.0));
                            }
                            if t.0 < now {
                                return Err("time went backwards".into());
                            }
                            now = t.0;
                            model[i].2 = MState::Fired;
                        }
                        (got, want) => {
                            return Err(format!(
                                "pop disagreement: queue {got:?}, \
                                 model {want:?}"));
                        }
                    }
                }
            }
            let live = model.iter().filter(|e| e.2 == MState::Live).count();
            if q.live_count() != live {
                return Err(format!(
                    "live_count {} != model {live}", q.live_count()));
            }
        }
        // Drain: everything still live fires, in model order.
        while let Some((t, v)) = q.pop() {
            let next = model
                .iter()
                .enumerate()
                .filter(|(_, e)| e.2 == MState::Live)
                .min_by(|(_, x), (_, y)| x.0.total_cmp(&y.0))
                .map(|(i, e)| (i, e.1));
            match next {
                Some((i, mv)) if mv == v => model[i].2 = MState::Fired,
                other => {
                    return Err(format!(
                        "drain pop ({}, {v}) but model says {other:?}",
                        t.0));
                }
            }
        }
        if model.iter().any(|e| e.2 == MState::Live) {
            return Err("live events lost at drain".into());
        }
        Ok(())
    });
}
