//! Streaming-ingestion acceptance suite.
//!
//! 1. `SynthSource ≡ Workload`: the default run (no explicit source)
//!    streams the materialized workload through `SynthSource`, and an
//!    explicitly wrapped source replays digest-identically — one
//!    submission path, proven, not assumed.
//! 2. Watermark invariance of outcomes: a bounded look-ahead replays
//!    byte-identically across all three engines, completes the same
//!    workload as the unbounded default, and its frontend peak stays
//!    within watermark + one block (the constant-memory contract).
//! 3. Trace-driven runs (CSV and generated arrivals) replay
//!    byte-identically across engines and drain every streamed job.
//! 4. A malformed trace fails the run with a clean `anyhow` error —
//!    before or mid-replay — never a panic or a hang.
//! 5. Dispatcher headroom batching (`max_blocks_per_barrier`) keeps
//!    per-mode byte-identity and is echoed in the report.
//!
//! `EVHC_PROPTEST_CASES` bounds the property case counts as in the
//! other suites.

use std::io::Cursor;

use evhc::cluster::{DispatchMode, Engine, HybridCluster, RunConfig,
                    RunReport};
use evhc::util::proptest::check_n;
use evhc::util::prng::Prng;
use evhc::workload::trace::{ArrivalGen, ArrivalProfile, CsvTrace,
                            SynthSource, WATERMARK_UNBOUNDED};

fn cases(default: u32) -> u32 {
    std::env::var("EVHC_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn run(cfg: RunConfig) -> Result<RunReport, String> {
    HybridCluster::new(cfg)
        .map_err(|e| e.to_string())?
        .run()
        .map_err(|e| e.to_string())
}

fn base_cfg(scale: f64, seed: u64, n_sites: usize,
            engine: Engine) -> RunConfig {
    let mut cfg = RunConfig::paper_usecase_sites(scale, seed, n_sites);
    cfg.inference_every = 0;
    cfg.engine = engine;
    cfg
}

/// Serial reference vs sharded and stealing replays: digests, recorder
/// transition streams and completion totals must agree, and the serial
/// run must complete exactly `total` jobs.
fn three_engine_identity(
    mk: &dyn Fn(Engine) -> RunConfig,
    total: u32,
    what: &str,
) -> Result<RunReport, String> {
    let reference = run(mk(Engine::Serial))?;
    if reference.jobs_completed != total {
        return Err(format!("{what}: serial completed {}/{total}",
                           reference.jobs_completed));
    }
    if reference.recorder.job_runs.len() != total as usize {
        return Err(format!(
            "{what}: serial recorded {} job runs for {total} jobs",
            reference.recorder.job_runs.len()));
    }
    let ref_digest = reference.determinism_digest();
    for engine in [Engine::Sharded { threads: 0 },
                   Engine::Stealing { threads: 0 }] {
        let r = run(mk(engine))?;
        if r.determinism_digest() != ref_digest {
            return Err(format!("{what}: {} diverged from serial",
                               engine.label()));
        }
        if r.recorder.transitions_named()
            != reference.recorder.transitions_named()
        {
            return Err(format!("{what}: {} transitions diverged",
                               engine.label()));
        }
    }
    Ok(reference)
}

// ---------------------------------------------------------------------
// SynthSource ≡ Workload
// ---------------------------------------------------------------------

/// The tentpole equivalence: a run with an explicit
/// `SynthSource::new(workload)` digests identically to the default run
/// that streams the same workload implicitly — and, because every run
/// now goes through the streaming frontend, identically to the
/// pre-streaming schedule.
#[test]
fn synth_source_is_digest_identical_to_the_default_run() {
    let implicit = run(base_cfg(0.02, 42, 3, Engine::Serial)).unwrap();
    let mut cfg = base_cfg(0.02, 42, 3, Engine::Serial);
    let total = cfg.workload.total_jobs();
    cfg.source = Some(Box::new(SynthSource::new(cfg.workload.clone())));
    let explicit = run(cfg).unwrap();
    assert_eq!(explicit.determinism_digest(),
               implicit.determinism_digest(),
               "explicit SynthSource diverged from the default run");
    assert_eq!(implicit.jobs_completed, total);
    // The unbounded default buffers the whole trace at workload start.
    assert_eq!(implicit.peak_buffered_jobs, total as u64);
    assert_eq!(implicit.max_blocks_per_barrier, 1);
}

/// Randomized SynthSource ≡ default across all three engines, both
/// dispatch modes.
#[test]
fn prop_synth_replay_matches_workload_on_all_engines() {
    #[derive(Debug)]
    struct Case {
        scale: f64,
        seed: u64,
        n_sites: usize,
        partitioned: bool,
    }
    let gen = |r: &mut Prng| Case {
        scale: r.uniform(0.015, 0.04),
        seed: r.next_u64(),
        n_sites: 2 + r.next_below(3) as usize,
        partitioned: r.chance(0.5),
    };
    check_n("synth-source ≡ workload", cases(4), gen, |case| {
        let mk = |engine: Engine, explicit: bool| {
            let mut cfg = base_cfg(case.scale, case.seed, case.n_sites,
                                   engine);
            if case.partitioned {
                cfg.dispatch = DispatchMode::Partitioned;
            }
            if explicit {
                cfg.source = Some(Box::new(
                    SynthSource::new(cfg.workload.clone())));
            }
            cfg
        };
        let total = mk(Engine::Serial, false).workload.total_jobs();
        let implicit = three_engine_identity(
            &|e| mk(e, false), total, "implicit")?;
        let explicit = three_engine_identity(
            &|e| mk(e, true), total, "explicit synth")?;
        if explicit.determinism_digest()
            != implicit.determinism_digest()
        {
            return Err("explicit synth diverged from default".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Bounded watermark: identity, completion, memory bound
// ---------------------------------------------------------------------

/// A small ingest watermark — blocks pulled a few at a time, each pop
/// triggering the next pull — must stay byte-identical across all
/// three engines, complete the same workload as the unbounded run, and
/// keep the frontend's peak within watermark + one block.
#[test]
fn bounded_watermark_replays_identically_and_bounds_memory() {
    let scale = 0.02;
    let mk = |engine: Engine, watermark: u32| {
        let mut cfg = base_cfg(scale, 7, 3, engine);
        cfg.ingest_watermark_jobs = watermark;
        cfg
    };
    let workload = mk(Engine::Serial, 1).workload.clone();
    let total = workload.total_jobs();
    let max_block =
        workload.blocks.iter().map(|b| b.jobs as u64).max().unwrap();
    let watermark = (total / 8).max(1);
    let bounded = three_engine_identity(
        &|e| mk(e, watermark), total, "bounded watermark").unwrap();
    assert!(bounded.peak_buffered_jobs
                <= watermark as u64 + max_block,
            "peak {} exceeds watermark {watermark} + block {max_block}",
            bounded.peak_buffered_jobs);
    assert!(bounded.peak_buffered_jobs < total as u64,
            "a bounded feed must never buffer the whole workload");
    // Same outcome as the unbounded default (timelines may differ in
    // event seq numbers, so totals — not digests — are compared).
    let unbounded = run(mk(Engine::Serial, WATERMARK_UNBOUNDED))
        .unwrap();
    assert_eq!(bounded.jobs_completed, unbounded.jobs_completed);
    assert_eq!(unbounded.peak_buffered_jobs, total as u64);
}

/// Same property under partitioned dispatch, randomized.
#[test]
fn prop_bounded_watermark_partitioned_identity() {
    #[derive(Debug)]
    struct Case {
        scale: f64,
        seed: u64,
        n_sites: usize,
        watermark: u32,
    }
    let gen = |r: &mut Prng| Case {
        scale: r.uniform(0.015, 0.04),
        seed: r.next_u64(),
        n_sites: 2 + r.next_below(3) as usize,
        watermark: 1 + r.next_below(64) as u32,
    };
    check_n("bounded watermark (partitioned)", cases(4), gen, |case| {
        let mk = |engine: Engine| {
            let mut cfg = base_cfg(case.scale, case.seed, case.n_sites,
                                   engine);
            cfg.dispatch = DispatchMode::Partitioned;
            cfg.ingest_watermark_jobs = case.watermark;
            cfg
        };
        let total = mk(Engine::Serial).workload.total_jobs();
        let r = three_engine_identity(&mk, total, "bounded-part")?;
        let workload = mk(Engine::Serial).workload.clone();
        let max_block =
            workload.blocks.iter().map(|b| b.jobs as u64).max()
                .unwrap();
        if r.peak_buffered_jobs > case.watermark as u64 + max_block {
            return Err(format!(
                "peak {} exceeds watermark {} + block {max_block}",
                r.peak_buffered_jobs, case.watermark));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Trace-driven runs: CSV and generated arrivals
// ---------------------------------------------------------------------

const SAMPLE_CSV: &str = "arrival_secs,jobs\n\
    0,30\n30,10\n# mid-trace comment\n60,25\n240,40\n600,45\n";
const SAMPLE_CSV_JOBS: u32 = 150;

fn csv_source() -> CsvTrace<Cursor<&'static [u8]>> {
    CsvTrace::from_reader(Cursor::new(SAMPLE_CSV.as_bytes()),
                          "sample.csv".into())
}

/// A CSV trace replaces the synthetic workload: all three engines
/// replay it byte-identically and complete exactly the streamed jobs.
#[test]
fn csv_trace_replays_byte_identically_on_all_engines() {
    for watermark in [WATERMARK_UNBOUNDED, 32] {
        let mk = |engine: Engine| {
            let mut cfg = base_cfg(0.02, 11, 3, engine);
            cfg.source = Some(Box::new(csv_source()));
            cfg.ingest_watermark_jobs = watermark;
            cfg
        };
        let r = three_engine_identity(&mk, SAMPLE_CSV_JOBS,
                                      "csv trace").unwrap();
        assert_eq!(r.jobs_completed, SAMPLE_CSV_JOBS);
    }
}

/// A generated burst/diurnal arrival process streams deterministically:
/// three-engine identity, exact completion, bounded look-ahead.
#[test]
fn generated_arrivals_replay_byte_identically_on_all_engines() {
    let total = 200u32;
    let profile = ArrivalProfile {
        base_rate: 2.0,
        window_s: 30.0,
        ..ArrivalProfile::default()
    };
    let mk = |engine: Engine| {
        let mut cfg = base_cfg(0.02, 13, 3, engine);
        cfg.dispatch = DispatchMode::Partitioned;
        cfg.source = Some(Box::new(
            ArrivalGen::new(13, total as u64, profile).unwrap()));
        cfg.ingest_watermark_jobs = 48;
        cfg
    };
    let r = three_engine_identity(&mk, total, "generated arrivals")
        .unwrap();
    assert_eq!(r.jobs_completed, total);
    assert!(r.peak_buffered_jobs < total as u64,
            "look-ahead must stay bounded below the trace total");
}

// ---------------------------------------------------------------------
// Malformed traces fail the run cleanly
// ---------------------------------------------------------------------

fn bad_csv(text: &'static str) -> CsvTrace<Cursor<&'static [u8]>> {
    CsvTrace::from_reader(Cursor::new(text.as_bytes()),
                          "broken.csv".into())
}

/// A trace that fails on the very first pull (empty / malformed head)
/// surfaces as a clean error from `run()` — never a panic.
#[test]
fn malformed_trace_fails_the_run_before_submission() {
    for text in ["", "# comments only\n", "not,a,row\n",
                 "60,10\n30,4\n"] {
        let mut cfg = base_cfg(0.02, 17, 2, Engine::Serial);
        cfg.source = Some(Box::new(bad_csv(text)));
        let Err(err) = run(cfg) else {
            panic!("malformed trace {text:?} must fail the run");
        };
        assert!(err.contains("trace source failed"),
                "unexpected error for {text:?}: {err}");
    }
}

/// A trace that breaks *mid-replay* (first block parsed and submitted,
/// second row malformed under a small watermark) still fails the run
/// cleanly after draining what was already scheduled.
#[test]
fn mid_replay_trace_error_fails_the_run_cleanly() {
    let mut cfg = base_cfg(0.02, 19, 2, Engine::Serial);
    cfg.source = Some(Box::new(bad_csv("0,5\n30,bogus\n")));
    cfg.ingest_watermark_jobs = 4; // first refill stops after row 1
    let Err(err) = run(cfg) else {
        panic!("mid-replay trace error must fail the run");
    };
    assert!(err.contains("trace source failed"), "{err}");
    assert!(err.contains("line 2"),
            "error should name the broken row: {err}");
}

// ---------------------------------------------------------------------
// Headroom batching
// ---------------------------------------------------------------------

/// `max_blocks_per_barrier > 1` keeps three-engine byte-identity under
/// partitioned dispatch and is echoed in the report; the centralized
/// mode ignores (but still echoes) the knob.
#[test]
fn headroom_batching_keeps_identity_and_is_reported() {
    let mk = |engine: Engine, k: u32| {
        let mut cfg = base_cfg(0.03, 29, 3, engine);
        cfg.dispatch = DispatchMode::Partitioned;
        cfg.dispatch_cfg.max_blocks_per_barrier = k;
        cfg
    };
    let total = mk(Engine::Serial, 4).workload.total_jobs();
    let r = three_engine_identity(&|e| mk(e, 4), total,
                                  "headroom k=4").unwrap();
    assert_eq!(r.max_blocks_per_barrier, 4);
    assert_eq!(r.jobs_completed, total);
    // Each k is individually deterministic (replay check).
    let again = run(mk(Engine::Serial, 4)).unwrap();
    assert_eq!(again.determinism_digest(), r.determinism_digest());
    // k = 1 is the classic route and the default echo.
    let classic = run(mk(Engine::Serial, 1)).unwrap();
    assert_eq!(classic.max_blocks_per_barrier, 1);
    assert_eq!(classic.jobs_completed, total);
}

/// Batched routing composes with a bounded streaming watermark: the
/// full stack (trace feed + batched leases) stays byte-identical on
/// all engines and drains every job.
#[test]
fn batched_routing_with_bounded_watermark_drains_everything() {
    let mk = |engine: Engine| {
        let mut cfg = base_cfg(0.025, 37, 3, engine);
        cfg.dispatch = DispatchMode::Partitioned;
        cfg.dispatch_cfg.max_blocks_per_barrier = 3;
        cfg.ingest_watermark_jobs = 16;
        cfg
    };
    let total = mk(Engine::Serial).workload.total_jobs();
    let r = three_engine_identity(&mk, total, "batched+bounded")
        .unwrap();
    assert_eq!(r.jobs_completed, total);
    assert_eq!(r.max_blocks_per_barrier, 3);
}
