//! Quickstart: deploy a small hybrid elastic cluster from the built-in
//! TOSCA template, run a reduced workload, and print the summary.
//!
//!     cargo run --release --example quickstart
//!
//! The whole 2-site deployment + elasticity cycle replays in well under a
//! second of wall-clock time on the discrete-event clock.

use evhc::cluster::{HybridCluster, RunConfig};

fn main() -> anyhow::Result<()> {
    evhc::util::logging::init(1);

    // The paper's scenario at 5% workload scale (~184 jobs).
    let cfg = RunConfig::paper_usecase(0.05, 7);
    let total_jobs = cfg.workload.total_jobs();
    println!("template: {}", cfg.template.name);
    println!("sites:    {}",
             cfg.sites.iter().map(|s| s.name.as_str())
                 .collect::<Vec<_>>().join(", "));
    println!("workload: {total_jobs} audio-classification jobs in {} blocks\n",
             cfg.workload.blocks.len());

    let report = HybridCluster::new(cfg)?.run()?;

    println!("--- timeline ---");
    for (t, m) in &report.recorder.milestones {
        println!("  {t} {m}");
    }

    println!("\n--- summary ---");
    println!("  jobs completed : {}/{total_jobs}", report.jobs_completed);
    println!("  makespan       : {}", report.makespan);
    println!("  total cost     : ${:.2}", report.total_cost_usd);
    println!("  paid util      : {:.0}%",
             report.paid_utilization() * 100.0);
    println!("  events         : {} ({:.3}s wall)", report.events,
             report.wall_secs);

    println!("\n--- per-VM ---");
    println!("  {:<14} {:<12} {:>7} {:>7} {:>8}", "name", "site", "hours",
             "busy", "cost");
    for r in &report.per_vm {
        println!("  {:<14} {:<12} {:>6.2}h {:>6.2}h {:>7.3}$",
                 r.name, r.site, r.hours, r.busy_hours, r.cost_usd);
    }
    Ok(())
}
