//! Redundant-star overlay (paper Fig. 6): five sites, two central points,
//! hot-backup failover when the primary CP dies, and restoration
//! semantics (clients stay on the backup until it fails in turn) —
//! then the same failure story at the cluster layer: a scripted
//! [`WanFaultPlan`] cuts a site off mid-run and the self-healing
//! control plane (retransmission, heartbeat quarantine, provisioning
//! failover) carries the workload through without losing a job.
//!
//!     cargo run --release --example multi_site_failover

use evhc::cluster::{HybridCluster, RunConfig, WanFaultPlan};
use evhc::netsim::{Cipher, LinkSpec, Network};
use evhc::sim::SimTime;
use evhc::vrouter::Overlay;

fn main() -> anyhow::Result<()> {
    evhc::util::logging::init(1);

    // Five research sites on a European WAN.
    let mut net = Network::new();
    let sites: Vec<_> = ["prague", "bari", "valencia", "karlsruhe", "lyon"]
        .iter()
        .map(|n| net.add_location(n))
        .collect();
    for (i, &a) in sites.iter().enumerate() {
        for &b in &sites[i + 1..] {
            net.set_link(a, b, LinkSpec::wan());
        }
    }

    // Redundant star: CPs at prague (primary) and bari (backup),
    // vRouters everywhere else.
    let mut ov = Overlay::new(Cipher::Aes256Gcm);
    ov.add_central_point("cp-prague", sites[0], 0x0A00_0000,
                         SimTime(0.0))?;
    ov.add_central_point("cp-bari", sites[1], 0x0A01_0000, SimTime(0.0))?;
    for (i, name) in ["vr-valencia", "vr-karlsruhe", "vr-lyon"]
        .iter()
        .enumerate()
    {
        let secs = ov.add_site_router(name, sites[i + 2],
                                      0x0A02_0000 + ((i as u32) << 8),
                                      SimTime(1.0))?;
        println!("{name} connected to primary CP in {secs:.1}s");
    }

    let lat_before = ov.latency(&net, "vr-valencia", "vr-lyon").unwrap();
    println!("\nvalencia→lyon via primary CP: {:.1} ms (path {:?})",
             lat_before * 1e3,
             ov.element_path("vr-valencia", "vr-lyon").unwrap());

    // --- primary CP failure --------------------------------------------
    println!("\n!!! primary CP (prague) fails");
    let rehomed = ov.fail_central_point("cp-prague", SimTime(100.0))?;
    println!("re-homed to backup CP: {rehomed:?}");
    assert_eq!(rehomed.len(), 3, "all three site routers must re-home");

    let lat_after = ov.latency(&net, "vr-valencia", "vr-lyon").unwrap();
    println!("valencia→lyon via backup CP:  {:.1} ms (path {:?})",
             lat_after * 1e3,
             ov.element_path("vr-valencia", "vr-lyon").unwrap());
    assert!(ov.is_connected("vr-valencia", "vr-lyon"));
    assert!(ov.is_connected("vr-karlsruhe", "cp-bari"));

    // --- restore: hot-backup semantics -----------------------------------
    ov.restore_central_point("cp-prague")?;
    let still_backup = ov.element("vr-valencia").unwrap().via_cp;
    println!("\nprimary restored; vr-valencia still routes via CP index \
              {still_backup:?} (hot-backup semantics — no fail-back)");

    // --- shortest-path extension (future work §5) -------------------------
    ov.shortest_path = true;
    let lat_direct = ov.latency(&net, "vr-valencia", "vr-lyon").unwrap();
    println!("\nwith shortest-path extension: valencia→lyon {:.1} ms \
              (direct tunnel, was {:.1} ms via CP)",
             lat_direct * 1e3, lat_after * 1e3);
    assert!(lat_direct < lat_after);

    println!("\nfailover scenario complete: connectivity preserved through \
              CP failure.");

    // --- WAN chaos on the full cluster (the self-healing layer) ----------
    // The paper pair (CESNET + AWS) with a degraded WAN to the AWS
    // site: 5% message loss while the cluster scales up, then a 900 s
    // partition that cuts the site off entirely. The silent site trips
    // the missed-heartbeat circuit breaker and is quarantined — its
    // leased jobs are requeued, new capacity fails over to other sites
    // — and when the partition heals, the quarantine closes and the
    // site rejoins. Faults delay work; they never lose it.
    println!("\n=== WAN chaos: loss -> partition -> quarantine -> \
              recovery ===");
    let mut cfg = RunConfig::paper_usecase(0.1, 7);
    cfg.inference_every = 0;
    cfg.faults = WanFaultPlan::new(9)
        .lossy(1, 0.0, 1500.0, 0.05)
        .partition(1, 1500.0, 900.0);
    let total = cfg.workload.total_jobs();
    let report = HybridCluster::new(cfg)?.run()?;
    println!("jobs completed    {} / {total} (makespan {:.0}s)",
             report.jobs_completed, report.makespan.0);
    println!("messages          {} dropped, {} duplicated, {} \
              retransmitted",
             report.messages_dropped, report.messages_duplicated,
             report.messages_retransmitted);
    println!("provisioning      {} retries, {} cross-site failovers",
             report.provision_retries, report.provision_failovers);
    println!("quarantine        {} window(s), {:.0}s total; {} leased \
              jobs requeued, {} recovered",
             report.quarantine_windows, report.quarantine_secs,
             report.lease_requeued_jobs, report.lease_recovered_jobs);
    println!("health trajectory (final / floor / first de-rank / first \
              quarantine):");
    let site_names = ["CESNET-MCC", "AWS"];
    for s in 0..report.site_health.len() {
        let fmt_t = |t: Option<f64>| match t {
            Some(v) => format!("{v:.0}s"),
            None => "never".to_string(),
        };
        println!("  {:<12} {:.3} / {:.3} / {} / {}",
                 site_names.get(s).copied().unwrap_or("?"),
                 report.site_health[s], report.site_health_min[s],
                 fmt_t(report.site_deranked_at[s]),
                 fmt_t(report.site_first_quarantine_at[s]));
    }
    assert_eq!(report.jobs_completed, total,
               "chaos must delay work, never lose it");
    // Adaptive placement contract: the degraded site (AWS, site 1)
    // must have decayed past the de-rank threshold strictly before the
    // missed-heartbeat breaker quarantined it — telemetry steers
    // capacity away while the reactive path is still counting misses.
    let deranked = report.site_deranked_at[1]
        .expect("the lossy site must cross the de-rank threshold");
    let quarantined = report.site_first_quarantine_at[1]
        .expect("the partition must trip the breaker");
    assert!(deranked < quarantined,
            "de-rank at {deranked:.0}s must precede the breaker at \
             {quarantined:.0}s");
    assert!(report.site_health_min[1] < report.site_health_min[0],
            "the faulted site must have the lower health floor");
    Ok(())
}
