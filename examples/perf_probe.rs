//! Perf probe: wall-clock of the full-scale DES replay and the figure
//! exporters — the measurements behind EXPERIMENTS.md §Perf (L3).
//!
//!     cargo run --release --example perf_probe

use evhc::cluster::{HybridCluster, RunConfig};

fn main() {
    let mut cfg = RunConfig::paper_usecase(1.0, 42);
    cfg.inference_every = 0;
    let t0 = std::time::Instant::now();
    let report = HybridCluster::new(cfg).unwrap().run().unwrap();
    let run_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = std::time::Instant::now();
    let f10 = report.recorder.fig10_usage(120.0, report.makespan);
    let fig10_ms = t1.elapsed().as_secs_f64() * 1e3;
    let t2 = std::time::Instant::now();
    let f11 = report.recorder.fig11_states(120.0, report.makespan);
    let fig11_ms = t2.elapsed().as_secs_f64() * 1e3;
    println!(
        "run={run_ms:.1}ms ({:.0}x real time) fig10={fig10_ms:.1}ms \
         ({} rows) fig11={fig11_ms:.1}ms ({} rows)",
        report.makespan.0 / (run_ms / 1e3),
        f10.len(),
        f11.len()
    );
}
