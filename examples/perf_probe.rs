//! Perf probe: wall-clock of the full-scale DES replay on every engine,
//! broken down by the engine profiler — where does a parallel replay
//! actually spend its time (shard windows vs the control barrier vs
//! injector waiting)? The measurements behind EXPERIMENTS.md §Perf.
//!
//!     cargo run --release --example perf_probe

use evhc::cluster::{Engine, HybridCluster, RunConfig};

fn main() {
    for engine in Engine::ALL {
        let mut cfg = RunConfig::paper_usecase(1.0, 42);
        cfg.inference_every = 0;
        cfg.engine = engine;
        let t0 = std::time::Instant::now();
        let report = HybridCluster::new(cfg).unwrap().run().unwrap();
        let run_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = std::time::Instant::now();
        let f10 = report.recorder.fig10_usage(120.0, report.makespan);
        let fig10_ms = t1.elapsed().as_secs_f64() * 1e3;
        let t2 = std::time::Instant::now();
        let f11 = report.recorder.fig11_states(120.0, report.makespan);
        let fig11_ms = t2.elapsed().as_secs_f64() * 1e3;
        println!(
            "{:<16} run={run_ms:.1}ms ({:.0}x real time) \
             fig10={fig10_ms:.1}ms ({} rows) fig11={fig11_ms:.1}ms \
             ({} rows)",
            engine.label(),
            report.makespan.0 / (run_ms / 1e3),
            f10.len(),
            f11.len()
        );
        match report.profile {
            None => {
                assert!(matches!(engine, Engine::Serial),
                        "parallel engines must carry a profile");
            }
            Some(p) => {
                assert!(!matches!(engine, Engine::Serial),
                        "serial runs must not carry a profile");
                assert!(p.windows > 0, "profiled run saw no windows");
                println!(
                    "                 windows={} serial_steps={} \
                     window={:.1}ms busiest-shard={:.1}ms \
                     barrier={:.1}ms ({:.0}% of run) \
                     injector-wait={:.1}ms chains={} \
                     parallel-efficiency={:.2}",
                    p.windows,
                    p.serial_steps,
                    p.window_wall_s * 1e3,
                    p.busiest_shard_wall_s * 1e3,
                    p.barrier_wall_s * 1e3,
                    p.barrier_fraction() * 100.0,
                    p.injector_wait_s * 1e3,
                    p.chains_executed,
                    p.parallel_efficiency()
                );
            }
        }
    }
}
