//! Heterogeneous multi-queue cluster — the paper's §5 future work:
//! "integration of both CPU and GPU based resources within the same
//! virtual cluster entity pooled from multiple cloud sites and made
//! available to users via different batch queues".
//!
//!     cargo run --release --example heterogeneous_queues
//!
//! Builds a PartitionedLrms with a `cpu` queue (SLURM, nodes pooled from
//! CESNET + AWS) and a `gpu` queue (nodes from AWS only), submits a mixed
//! preprocessing/training workload, and shows per-queue backlogs scaling
//! independently.

use evhc::lrms::{PartitionedLrms, Slurm};
use evhc::sim::SimTime;
use evhc::util::plot::barchart;

fn main() -> anyhow::Result<()> {
    evhc::util::logging::init(1);

    let mut cluster = PartitionedLrms::new();
    cluster.add_partition("cpu", Box::new(Slurm::new()))?;
    cluster.add_partition("gpu", Box::new(Slurm::new()))?;

    // CPU pool spans both sites (4 nodes); GPU pool is AWS-only (1 node),
    // mirroring how research clouds rarely expose accelerators.
    for (node, slots) in [("cesnet-cpu-1", 2), ("cesnet-cpu-2", 2),
                          ("aws-cpu-1", 2), ("aws-cpu-2", 2)] {
        cluster.register_node("cpu", node, slots, SimTime(0.0))?;
    }
    cluster.register_node("gpu", "aws-gpu-1", 1, SimTime(0.0))?;

    // Mixed workload: 20 preprocessing jobs (cpu) feeding 8 training
    // jobs (gpu).
    let mut ids = Vec::new();
    for i in 0..20 {
        ids.push(cluster.submit("cpu", &format!("preproc-{i}"), 1,
                                SimTime(1.0))?);
    }
    for i in 0..8 {
        ids.push(cluster.submit("gpu", &format!("train-{i}"), 1,
                                SimTime(1.0))?);
    }

    let assigned = cluster.schedule(SimTime(2.0));
    println!("first sweep placed {} jobs:", assigned.len());
    for (job, node) in &assigned {
        let j = cluster.job(*job).unwrap();
        println!("  {:<12} -> {node}", j.name);
    }

    let pending = cluster.pending_per_partition();
    let rows: Vec<(String, f64)> = pending
        .iter()
        .map(|(q, n)| (q.to_string(), *n as f64))
        .collect();
    println!("\n{}", barchart("pending jobs per queue after sweep 1",
                              &rows, 30));

    // The CPU queue drains quickly (8 slots); the GPU queue backlogs on
    // its single accelerator — the signal CLUES would use to burst GPU
    // capacity from another cloud.
    let cpu_pending = pending.iter().find(|(q, _)| *q == "cpu").unwrap().1;
    let gpu_pending = pending.iter().find(|(q, _)| *q == "gpu").unwrap().1;
    assert_eq!(cpu_pending, 20 - 8);
    assert_eq!(gpu_pending, 8 - 1);

    // Drain everything, 30 virtual seconds per job.
    let mut t = 2.0;
    let mut running: Vec<_> = assigned.clone();
    let mut completed = 0;
    while completed < ids.len() {
        t += 30.0;
        for (job, _) in running.drain(..) {
            cluster.on_job_finished(job, true, SimTime(t))?;
            completed += 1;
        }
        running = cluster.schedule(SimTime(t));
    }
    println!("all {} jobs completed by t={}s; gpu queue was the \
              bottleneck as expected", ids.len(), t);
    Ok(())
}
