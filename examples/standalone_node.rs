//! Stand-alone nodes (paper §3.5.4): joining machines that live in
//! networks the vRouter cannot take over — a user's workstation and a
//! node in a cloud without private-network support — directly into the
//! deployment VPN.
//!
//!     cargo run --release --example standalone_node

use evhc::cloudsim::SiteSpec;
use evhc::netsim::{Cipher, LinkSpec, Network};
use evhc::sim::SimTime;
use evhc::vrouter::{Overlay, Role};

fn main() -> anyhow::Result<()> {
    evhc::util::logging::init(1);

    let mut net = Network::new();
    let cesnet = net.add_location("cesnet");
    let aws = net.add_location("aws");
    let home = net.add_location("home-isp");
    let legacy = net.add_location("legacy-cloud");
    net.set_link(cesnet, aws, LinkSpec::transatlantic());
    net.set_link(cesnet, home,
                 LinkSpec { latency_s: 0.012, bandwidth_bps: 1.25e7 });
    net.set_link(cesnet, legacy, LinkSpec::wan());

    // A site whose cloud will NOT let users create private networks —
    // the §3.5.4 condition that forces stand-alone mode.
    let mut spec = SiteSpec::opennebula("legacy-cloud");
    spec.supports_private_networks = false;
    let mut site = evhc::cloudsim::CloudSite::new(spec, 3, legacy, 9);
    let err = site.create_network("dep-net").unwrap_err();
    println!("legacy-cloud refuses private networks: {err}");

    // Normal star with the CP at CESNET's front-end.
    let mut ov = Overlay::new(Cipher::Aes128Gcm);
    ov.add_central_point("front-end", cesnet, 0x0A00_0000, SimTime(0.0))?;
    ov.add_site_router("vr-aws", aws, 0x0A01_0000, SimTime(1.0))?;

    // 1. The user's workstation joins from home.
    let secs = ov.add_standalone("laptop", home, SimTime(2.0))?;
    println!("laptop joined the VPN in {secs:.1}s (client runs on the \
              node itself)");

    // 2. A worker in the legacy cloud joins as a stand-alone node too.
    let secs = ov.add_standalone("legacy-wn", legacy, SimTime(3.0))?;
    println!("legacy-wn joined the VPN in {secs:.1}s\n");

    // Full visibility across the deployment, as the paper requires.
    for (a, b) in [("laptop", "front-end"), ("laptop", "vr-aws"),
                   ("legacy-wn", "vr-aws"), ("laptop", "legacy-wn")] {
        let path = ov.element_path(a, b).unwrap();
        let lat = ov.latency(&net, a, b).unwrap();
        println!("{a:>10} → {b:<10}: {:.1} ms via {path:?}", lat * 1e3);
        assert!(ov.is_connected(a, b));
    }

    // Stand-alone nodes own no subnet — the CP routes their /32 only.
    assert_eq!(ov.element("laptop").unwrap().role, Role::Standalone);
    assert_eq!(ov.element("laptop").unwrap().subnet_base, None);

    // The trade-off from §3.5.4: the orchestration layer had to install
    // the VPN client on the node itself (no "black-box" images), which
    // the CA records as a directly-issued client certificate.
    assert!(ov.ca.verify("laptop"));
    println!("\nCA has {} live identities (CP + site router + 2 \
              stand-alone clients)", ov.ca.issued_count());
    Ok(())
}
