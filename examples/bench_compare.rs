//! Compare a fresh `BENCH_scale.json` against the committed
//! `BENCH_baseline.json`, printing an events/sec and ms/tick table per
//! scenario/stealing/cluster section, the streaming-`trace` jobs/sec
//! diff (RSS warn-only), plus the broker cost/makespan diff, the
//! WAN-chaos recovery-overhead diff (both the fixed `chaos` variants
//! and the `chaos_sweep` retry-knob frontier) and the `perf_profile`
//! engine-profiler / tracing-overhead diff.
//!
//! Regression policy:
//! * events/sec drops beyond 10% are warned about; beyond 15% they are
//!   *gating* — with `EVHC_BENCH_GATE=1` (set by `ci.sh`) the process
//!   exits non-zero. Cost/makespan (broker), recovery overhead and
//!   completed-jobs/sec (chaos), recorder-bytes (stealing) and the
//!   engine-profiler breakdown (perf_profile) drifts stay warn-only
//!   in every mode — the profiler numbers are pure wall-clock.
//! * the one absolute gate: the fresh run's tracing throughput ratio
//!   (events/sec with tracing on over tracing off, measured within a
//!   single bench run so machine noise cancels) must stay >= 0.9 —
//!   an observability layer costing more than 10% has broken its own
//!   contract.
//! * without `EVHC_BENCH_GATE=1` everything is warn-only (exit 0).
//!
//!     cargo run --release --example bench_compare -- \
//!         BENCH_baseline.json BENCH_scale.json

use evhc::api::json::{parse, Json};

/// events/sec regression beyond this is worth a warning.
const WARN_PCT: f64 = 10.0;
/// events/sec regression beyond this fails the gate.
const GATE_PCT: f64 = 15.0;
/// The fresh run's tracing-on/tracing-off events/sec ratio below this
/// fails the gate: tracing may cost at most 10% of throughput.
const TRACE_RATIO_GATE: f64 = 0.9;

/// Sections of a `scenarios` row that carry Measured-shaped objects.
const SECTIONS: &[(&str, &[&str])] = &[
    ("indexed", &["indexed"]),
    ("naive", &["naive"]),
    ("sharded/single_queue", &["sharded", "single_queue"]),
    ("sharded/parallel", &["sharded", "parallel"]),
];

/// Sections of a `stealing` row that carry Measured-shaped objects.
const STEAL_SECTIONS: &[(&str, &[&str])] = &[
    ("single_queue", &["single_queue"]),
    ("parallel", &["parallel"]),
    ("stealing", &["stealing"]),
    ("stealing_spill", &["stealing_spill"]),
];

/// Sections of a `cluster` row (the real paper use case per engine).
const CLUSTER_SECTIONS: &[(&str, &[&str])] = &[
    ("serial", &["serial"]),
    ("sharded", &["sharded"]),
    ("stealing", &["stealing"]),
    ("stealing_spill", &["stealing_spill"]),
];

fn lookup<'a>(row: &'a Json, path: &[&str]) -> Option<&'a Json> {
    let mut cur = row;
    for &key in path {
        cur = cur.get(key)?;
    }
    Some(cur)
}

fn metric(row: &Json, path: &[&str], name: &str) -> Option<f64> {
    lookup(row, path)?.get(name)?.as_f64()
}

fn rows_of<'a>(doc: &'a Json, key: &str) -> Vec<(String, &'a Json)> {
    let Some(Json::Array(rows)) = doc.get(key) else {
        return Vec::new();
    };
    rows.iter()
        .filter_map(|r| {
            r.get("name")
                .and_then(|n| n.as_str())
                .map(|n| (n.to_string(), r))
        })
        .collect()
}

/// Tallies of a comparison pass: sections warned about (>10% slower)
/// and sections that fail the gate (>15% slower).
#[derive(Default)]
struct Tally {
    warned: u32,
    gated: u32,
}

/// Diff the Measured-shaped `sections` of every named row under `key`,
/// comparing events/sec (regression-tracked) and ms/tick (printed).
fn compare_measured(baseline: &Json, fresh: &Json, key: &str,
                    sections: &[(&str, &[&str])]) -> Tally {
    let base_rows = rows_of(baseline, key);
    let fresh_rows = rows_of(fresh, key);
    let mut tally = Tally::default();
    if fresh_rows.is_empty() {
        return tally;
    }
    println!("\n[{key}]");
    println!("{:<22} {:<22} {:>14} {:>14} {:>8}", "row", "section",
             "base ev/s", "fresh ev/s", "delta");
    println!("{}", "-".repeat(84));
    for (name, fresh_row) in fresh_rows {
        let Some((_, base_row)) =
            base_rows.iter().find(|(n, _)| *n == name)
        else {
            println!("{name:<22} (new row, no baseline)");
            continue;
        };
        for &(label, path) in sections {
            let (Some(b), Some(f)) = (
                metric(base_row, path, "events_per_sec"),
                metric(fresh_row, path, "events_per_sec"),
            ) else {
                continue;
            };
            let delta = if b > 0.0 { (f - b) / b * 100.0 } else { 0.0 };
            let mark = if delta < -GATE_PCT {
                tally.warned += 1;
                tally.gated += 1;
                "  <-- REGRESSION (gate)"
            } else if delta < -WARN_PCT {
                tally.warned += 1;
                "  <-- REGRESSION"
            } else {
                ""
            };
            println!("{name:<22} {label:<22} {b:>14.0} {f:>14.0} \
                      {delta:>+7.1}%{mark}");
            if let (Some(bm), Some(fm)) = (
                metric(base_row, path, "ms_per_tick"),
                metric(fresh_row, path, "ms_per_tick"),
            ) {
                let dm = if bm > 0.0 { (fm - bm) / bm * 100.0 } else { 0.0 };
                println!("{:<22} {:<22} {bm:>11.4} ms {fm:>11.4} ms \
                          {dm:>+7.1}%", "", "  ms/tick");
            }
        }
        // Recorder-memory trajectory (stealing rows): warn-only.
        for bytes_metric in ["recorder_bytes_in_memory",
                             "recorder_spill_file_bytes"] {
            let (Some(b), Some(f)) = (
                base_row.get(bytes_metric).and_then(|v| v.as_f64()),
                fresh_row.get(bytes_metric).and_then(|v| v.as_f64()),
            ) else {
                continue;
            };
            if b == f {
                continue;
            }
            let delta = if b > 0.0 {
                (f - b) / b * 100.0
            } else {
                f64::INFINITY
            };
            let mark = if delta > WARN_PCT { "  <-- GREW (warn-only)" }
                       else { "" };
            println!("{name:<22} {bytes_metric:<22} {b:>14.0} {f:>14.0} \
                      {delta:>+7.1}%{mark}");
        }
    }
    tally
}

/// Diff the `trace` rows (streaming replay): per-engine jobs/sec is
/// regression-tracked exactly like events/sec elsewhere (>10% warns,
/// >15% gates under `EVHC_BENCH_GATE=1`); RSS is machine-dependent
/// wall-state and stays warn-only, like the recorder-bytes trajectory.
fn compare_trace(baseline: &Json, fresh: &Json) -> Tally {
    let base_rows = rows_of(baseline, "trace");
    let fresh_rows = rows_of(fresh, "trace");
    let mut tally = Tally::default();
    if fresh_rows.is_empty() {
        return tally;
    }
    println!("\n[trace]");
    println!("{:<22} {:<22} {:>14} {:>14} {:>8}", "row", "engine",
             "base jobs/s", "fresh jobs/s", "delta");
    println!("{}", "-".repeat(84));
    for (name, fresh_row) in fresh_rows {
        let Some((_, base_row)) =
            base_rows.iter().find(|(n, _)| *n == name)
        else {
            println!("{name:<22} (new row, no baseline)");
            continue;
        };
        for engine in ["serial", "sharded", "stealing"] {
            let (Some(b), Some(f)) = (
                metric(base_row, &[engine], "jobs_per_sec"),
                metric(fresh_row, &[engine], "jobs_per_sec"),
            ) else {
                continue;
            };
            let delta = if b > 0.0 { (f - b) / b * 100.0 } else { 0.0 };
            let mark = if delta < -GATE_PCT {
                tally.warned += 1;
                tally.gated += 1;
                "  <-- REGRESSION (gate)"
            } else if delta < -WARN_PCT {
                tally.warned += 1;
                "  <-- REGRESSION"
            } else {
                ""
            };
            println!("{name:<22} {engine:<22} {b:>14.0} {f:>14.0} \
                      {delta:>+7.1}%{mark}");
            // RSS trajectory: warn-only (machine- and allocator-
            // dependent; the deterministic memory bound is asserted
            // in-bench via peak_buffered_jobs).
            if let (Some(bm), Some(fm)) = (
                metric(base_row, &[engine], "rss_mb"),
                metric(fresh_row, &[engine], "rss_mb"),
            ) {
                if bm != fm && bm > 0.0 {
                    let dm = (fm - bm) / bm * 100.0;
                    let mark = if dm > WARN_PCT {
                        "  <-- GREW (warn-only)"
                    } else {
                        ""
                    };
                    println!("{:<22} {:<22} {bm:>11.0} MB {fm:>11.0} MB \
                              {dm:>+7.1}%{mark}", "", "  rss");
                }
            }
        }
    }
    tally
}

/// Diff the broker policy×scenario rows: cost and makespan are the
/// broker's figures of merit (events/sec is noise at this size).
/// Always warn-only.
fn compare_broker(baseline: &Json, fresh: &Json) -> u32 {
    let base_rows = rows_of(baseline, "broker");
    let fresh_rows = rows_of(fresh, "broker");
    if fresh_rows.is_empty() {
        return 0;
    }
    println!("\n{:<28} {:>12} {:>12} {:>8}", "broker row", "base", "fresh",
             "delta");
    println!("{}", "-".repeat(64));
    let mut regressions = 0u32;
    for (name, row) in fresh_rows {
        let Some((_, base_row)) =
            base_rows.iter().find(|(n, _)| *n == name)
        else {
            println!("{name:<28} (new row, no baseline)");
            continue;
        };
        for metric_name in ["makespan_s", "cost_usd",
                            "preempt_recovered"] {
            let (Some(b), Some(f)) = (
                base_row.get(metric_name).and_then(|v| v.as_f64()),
                row.get(metric_name).and_then(|v| v.as_f64()),
            ) else {
                continue;
            };
            if b == f {
                continue; // deterministic scenarios: only drift matters
            }
            // A metric growing from a zero baseline (e.g. a formerly
            // free run starting to cost money) is an unbounded
            // regression, not a 0% one.
            let delta = if b != 0.0 {
                (f - b) / b * 100.0
            } else {
                f64::INFINITY
            };
            // A scenario getting >10% slower or pricier is a
            // regression in the broker's own currency.
            let mark = if metric_name != "preempt_recovered"
                && delta > WARN_PCT
            {
                regressions += 1;
                "  <-- REGRESSION"
            } else {
                ""
            };
            println!("{name:<28} {b:>12.4} {f:>12.4} {delta:>+7.1}% \
                      ({metric_name}){mark}");
        }
    }
    regressions
}

/// Diff the WAN-chaos rows (`key` is `"chaos"` or `"chaos_sweep"` —
/// both sections share the row shape): recovery overhead (chaos
/// makespan over the fault-free reference) and completed-jobs/sec.
/// Always warn-only — the rows mix simulated recovery behaviour with
/// wall-clock throughput, so they chart the self-healing trajectory
/// without ever gating CI.
fn compare_chaos(baseline: &Json, fresh: &Json, key: &str) -> u32 {
    let base_rows = rows_of(baseline, key);
    let fresh_rows = rows_of(fresh, key);
    if fresh_rows.is_empty() {
        return 0;
    }
    println!("\n{:<28} {:>12} {:>12} {:>8}", format!("{key} row"),
             "base", "fresh", "delta");
    println!("{}", "-".repeat(64));
    let mut regressions = 0u32;
    for (name, row) in fresh_rows {
        let Some((_, base_row)) =
            base_rows.iter().find(|(n, _)| *n == name)
        else {
            println!("{name:<28} (new row, no baseline)");
            continue;
        };
        for metric_name in ["recovery_overhead", "completed_jobs_per_sec",
                            "messages_retransmitted",
                            "quarantine_windows"] {
            let (Some(b), Some(f)) = (
                base_row.get(metric_name).and_then(|v| v.as_f64()),
                row.get(metric_name).and_then(|v| v.as_f64()),
            ) else {
                continue;
            };
            if b == f {
                continue; // deterministic chaos: only drift matters
            }
            let delta = if b != 0.0 {
                (f - b) / b * 100.0
            } else {
                f64::INFINITY
            };
            // Self-healing getting >10% more expensive (longer
            // recovery, fewer jobs through) is worth a warning; the
            // raw fault counters are informational only.
            let worse = match metric_name {
                "recovery_overhead" => delta > WARN_PCT,
                "completed_jobs_per_sec" => delta < -WARN_PCT,
                _ => false,
            };
            let mark = if worse {
                regressions += 1;
                "  <-- REGRESSION (warn-only)"
            } else {
                ""
            };
            println!("{name:<28} {b:>12.4} {f:>12.4} {delta:>+7.1}% \
                      ({metric_name}){mark}");
        }
    }
    regressions
}

/// Diff the `perf_profile` section: the per-engine profiler breakdown
/// and the serial tracing-overhead probe. Profile numbers are pure
/// wall-clock and therefore warn-only; the tracing throughput ratio is
/// the one absolute check — it compares the fresh run against itself
/// (tracing on vs off within one bench invocation), so machine noise
/// largely cancels and a ratio below [`TRACE_RATIO_GATE`] gates.
fn compare_perf_profile(baseline: &Json, fresh: &Json) -> Tally {
    let mut tally = Tally::default();
    let Some(fresh_pp) = fresh.get("perf_profile") else {
        return tally; // fresh bench predates the profiler section
    };
    println!("\n[perf_profile]");
    let base_pp = baseline.get("perf_profile");
    // Quick and full bench runs profile different scales; only diff
    // against the baseline when both measured the same workload.
    let comparable = match (
        base_pp.and_then(|b| b.get("name")).and_then(|n| n.as_str()),
        fresh_pp.get("name").and_then(|n| n.as_str()),
    ) {
        (Some(b), Some(f)) if b == f => true,
        (Some(b), Some(f)) => {
            println!("(scale changed {b} -> {f}; baseline diff skipped)");
            false
        }
        (None, _) => {
            println!("(baseline predates perf_profile; fresh-only \
                      checks)");
            false
        }
        _ => false,
    };

    for engine in ["sharded", "stealing"] {
        let Some(fresh_eng) = fresh_pp.get(engine) else {
            continue;
        };
        let ev = metric(fresh_eng, &["measured"], "events_per_sec");
        let bf = metric(fresh_eng, &["profile"], "barrier_fraction");
        let pe = metric(fresh_eng, &["profile"], "parallel_efficiency");
        if let (Some(ev), Some(bf), Some(pe)) = (ev, bf, pe) {
            println!("{engine:<14} {ev:>10.0} ev/s  \
                      barrier={:.1}%  par-eff={pe:.2}", bf * 100.0);
        }
        if !comparable {
            continue;
        }
        let base_eng = base_pp.and_then(|b| b.get(engine));
        for (label, path, name) in [
            ("events_per_sec", &["measured"][..], "events_per_sec"),
            ("parallel_efficiency", &["profile"][..],
             "parallel_efficiency"),
        ] {
            let (Some(b), Some(f)) = (
                base_eng.and_then(|r| metric(r, path, name)),
                metric(fresh_eng, path, name),
            ) else {
                continue;
            };
            let delta = if b > 0.0 { (f - b) / b * 100.0 } else { 0.0 };
            let mark = if delta < -WARN_PCT {
                tally.warned += 1;
                "  <-- REGRESSION (warn-only)"
            } else {
                ""
            };
            println!("{engine:<14} {label:<22} {b:>12.2} {f:>12.2} \
                      {delta:>+7.1}%{mark}");
        }
    }

    // The tracing-overhead gate, always evaluated on the fresh run
    // alone: ratio_on_vs_off is (events/sec traced) / (untraced).
    if let Some(ratio) = fresh_pp
        .get("tracing")
        .and_then(|t| t.get("ratio_on_vs_off"))
        .and_then(|v| v.as_f64())
    {
        let mark = if ratio < TRACE_RATIO_GATE {
            tally.warned += 1;
            tally.gated += 1;
            "  <-- TRACING OVERHEAD (gate)"
        } else {
            ""
        };
        println!("tracing        on/off ratio {ratio:>12.3} (gate at \
                  {TRACE_RATIO_GATE:.2}){mark}");
        if comparable {
            if let Some(b) = base_pp
                .and_then(|b| b.get("tracing"))
                .and_then(|t| t.get("ratio_on_vs_off"))
                .and_then(|v| v.as_f64())
            {
                println!("tracing        baseline ratio {b:>10.3}");
            }
        }
    }
    tally
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if args.len() != 3 {
        eprintln!("usage: bench_compare <baseline.json> <fresh.json>");
        std::process::exit(2);
    }
    let read = |path: &str| -> Json {
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("reading {path}: {e}"));
        parse(&text).unwrap_or_else(|e| panic!("parsing {path}: {e}"))
    };
    let baseline = read(&args[1]);
    let fresh = read(&args[2]);
    let gate_on = std::env::var("EVHC_BENCH_GATE")
        .map(|v| v == "1")
        .unwrap_or(false);

    if baseline
        .get("synthetic_seed")
        .and_then(|v| v.as_bool())
        .unwrap_or(false)
    {
        println!("NOTE: the committed baseline is a synthetic low-water \
                  seed;\nrefresh it with './ci.sh bench seed-baseline' \
                  on a quiet machine\nand commit the result to tighten \
                  the gate.");
    }

    let scen = compare_measured(&baseline, &fresh, "scenarios", SECTIONS);
    let steal =
        compare_measured(&baseline, &fresh, "stealing", STEAL_SECTIONS);
    let cluster =
        compare_measured(&baseline, &fresh, "cluster", CLUSTER_SECTIONS);
    let trace = compare_trace(&baseline, &fresh);
    let broker_regressions = compare_broker(&baseline, &fresh);
    let chaos_regressions = compare_chaos(&baseline, &fresh, "chaos")
        + compare_chaos(&baseline, &fresh, "chaos_sweep");
    let profile = compare_perf_profile(&baseline, &fresh);

    let warned = scen.warned + steal.warned + cluster.warned
        + trace.warned + profile.warned;
    let gated = scen.gated + steal.gated + cluster.gated + trace.gated
        + profile.gated;
    if warned > 0 || broker_regressions > 0 || chaos_regressions > 0 {
        println!("\nWARNING: {warned} section(s) regressed by more than \
                  {WARN_PCT}% events/sec ({gated} gating), \
                  {broker_regressions} broker row(s) by more \
                  than {WARN_PCT}% cost/makespan and \
                  {chaos_regressions} chaos row(s) by more than \
                  {WARN_PCT}% recovery overhead (both warn-only).");
    } else {
        println!("\nno regressions beyond {WARN_PCT}%.");
    }
    if gate_on && gated > 0 {
        eprintln!("FAIL: {gated} section(s) regressed beyond the gate \
                   ({GATE_PCT}% events/sec, or tracing overhead past \
                   {TRACE_RATIO_GATE:.2}) with EVHC_BENCH_GATE=1.");
        std::process::exit(1);
    }
    if gate_on {
        println!("gate: no events/sec regression beyond {GATE_PCT}% and \
                  tracing overhead within budget.");
    }
}
