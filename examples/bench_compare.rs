//! Compare a fresh `BENCH_scale.json` against the committed
//! `BENCH_baseline.json`, printing an events/sec and ms/tick table per
//! scenario/section. Warn-only: regressions are reported loudly but the
//! exit code stays 0 — `ci.sh` runs this after every bench pass.
//!
//!     cargo run --release --example bench_compare -- \
//!         BENCH_baseline.json BENCH_scale.json

use evhc::api::json::{parse, Json};

/// Sections of a scenario row that carry Measured-shaped objects.
const SECTIONS: &[(&str, &[&str])] = &[
    ("indexed", &["indexed"]),
    ("naive", &["naive"]),
    ("sharded/single_queue", &["sharded", "single_queue"]),
    ("sharded/parallel", &["sharded", "parallel"]),
];

fn lookup<'a>(row: &'a Json, path: &[&str]) -> Option<&'a Json> {
    let mut cur = row;
    for &key in path {
        cur = cur.get(key)?;
    }
    Some(cur)
}

fn metric(row: &Json, path: &[&str], name: &str) -> Option<f64> {
    lookup(row, path)?.get(name)?.as_f64()
}

fn rows_of<'a>(doc: &'a Json, key: &str) -> Vec<(String, &'a Json)> {
    let Some(Json::Array(rows)) = doc.get(key) else {
        return Vec::new();
    };
    rows.iter()
        .filter_map(|r| {
            r.get("name")
                .and_then(|n| n.as_str())
                .map(|n| (n.to_string(), r))
        })
        .collect()
}

fn scenarios(doc: &Json) -> Vec<(String, &Json)> {
    rows_of(doc, "scenarios")
}

/// Diff the broker policy×scenario rows: cost and makespan are the
/// broker's figures of merit (events/sec is noise at this size).
fn compare_broker(baseline: &Json, fresh: &Json) -> u32 {
    let base_rows = rows_of(baseline, "broker");
    let fresh_rows = rows_of(fresh, "broker");
    if fresh_rows.is_empty() {
        return 0;
    }
    println!("\n{:<28} {:>12} {:>12} {:>8}", "broker row", "base", "fresh",
             "delta");
    println!("{}", "-".repeat(64));
    let mut regressions = 0u32;
    for (name, row) in fresh_rows {
        let Some((_, base_row)) =
            base_rows.iter().find(|(n, _)| *n == name)
        else {
            println!("{name:<28} (new row, no baseline)");
            continue;
        };
        for metric_name in ["makespan_s", "cost_usd",
                            "preempt_recovered"] {
            let (Some(b), Some(f)) = (
                base_row.get(metric_name).and_then(|v| v.as_f64()),
                row.get(metric_name).and_then(|v| v.as_f64()),
            ) else {
                continue;
            };
            if b == f {
                continue; // deterministic scenarios: only drift matters
            }
            // A metric growing from a zero baseline (e.g. a formerly
            // free run starting to cost money) is an unbounded
            // regression, not a 0% one.
            let delta = if b != 0.0 {
                (f - b) / b * 100.0
            } else {
                f64::INFINITY
            };
            // A scenario getting >10% slower or pricier is a
            // regression in the broker's own currency.
            let mark = if metric_name != "preempt_recovered"
                && delta > 10.0
            {
                regressions += 1;
                "  <-- REGRESSION"
            } else {
                ""
            };
            println!("{name:<28} {b:>12.4} {f:>12.4} {delta:>+7.1}% \
                      ({metric_name}){mark}");
        }
    }
    regressions
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if args.len() != 3 {
        eprintln!("usage: bench_compare <baseline.json> <fresh.json>");
        std::process::exit(2);
    }
    let read = |path: &str| -> Json {
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("reading {path}: {e}"));
        parse(&text).unwrap_or_else(|e| panic!("parsing {path}: {e}"))
    };
    let baseline = read(&args[1]);
    let fresh = read(&args[2]);

    println!("{:<22} {:<22} {:>14} {:>14} {:>8}", "scenario", "section",
             "base ev/s", "fresh ev/s", "delta");
    println!("{}", "-".repeat(84));
    let mut regressions = 0u32;
    let base_rows = scenarios(&baseline);
    for (name, fresh_row) in scenarios(&fresh) {
        let Some((_, base_row)) =
            base_rows.iter().find(|(n, _)| *n == name)
        else {
            println!("{name:<22} (new scenario, no baseline)");
            continue;
        };
        for &(label, path) in SECTIONS {
            let (Some(b), Some(f)) = (
                metric(base_row, path, "events_per_sec"),
                metric(fresh_row, path, "events_per_sec"),
            ) else {
                continue;
            };
            let delta = if b > 0.0 { (f - b) / b * 100.0 } else { 0.0 };
            let mark = if delta < -10.0 {
                regressions += 1;
                "  <-- REGRESSION"
            } else {
                ""
            };
            println!("{name:<22} {label:<22} {b:>14.0} {f:>14.0} \
                      {delta:>+7.1}%{mark}");
            if let (Some(bm), Some(fm)) = (
                metric(base_row, path, "ms_per_tick"),
                metric(fresh_row, path, "ms_per_tick"),
            ) {
                let dm = if bm > 0.0 { (fm - bm) / bm * 100.0 } else { 0.0 };
                println!("{:<22} {:<22} {bm:>11.4} ms {fm:>11.4} ms \
                          {dm:>+7.1}%", "", "  ms/tick");
            }
        }
    }
    let broker_regressions = compare_broker(&baseline, &fresh);
    if regressions > 0 || broker_regressions > 0 {
        println!("\nWARNING: {regressions} section(s) regressed by more \
                  than 10% events/sec and {broker_regressions} broker \
                  row(s) by more than 10% cost/makespan (warn-only).");
    } else {
        println!("\nno regressions beyond 10%.");
    }
}
