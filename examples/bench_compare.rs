//! Compare a fresh `BENCH_scale.json` against the committed
//! `BENCH_baseline.json`, printing an events/sec and ms/tick table per
//! scenario/stealing/cluster section plus the broker cost/makespan
//! diff and the WAN-chaos recovery-overhead diff (both the fixed
//! `chaos` variants and the `chaos_sweep` retry-knob frontier).
//!
//! Regression policy:
//! * events/sec drops beyond 10% are warned about; beyond 15% they are
//!   *gating* — with `EVHC_BENCH_GATE=1` (set by `ci.sh`) the process
//!   exits non-zero. Cost/makespan (broker), recovery overhead and
//!   completed-jobs/sec (chaos) and recorder-bytes (stealing) drifts
//!   stay warn-only in every mode.
//! * without `EVHC_BENCH_GATE=1` everything is warn-only (exit 0).
//!
//!     cargo run --release --example bench_compare -- \
//!         BENCH_baseline.json BENCH_scale.json

use evhc::api::json::{parse, Json};

/// events/sec regression beyond this is worth a warning.
const WARN_PCT: f64 = 10.0;
/// events/sec regression beyond this fails the gate.
const GATE_PCT: f64 = 15.0;

/// Sections of a `scenarios` row that carry Measured-shaped objects.
const SECTIONS: &[(&str, &[&str])] = &[
    ("indexed", &["indexed"]),
    ("naive", &["naive"]),
    ("sharded/single_queue", &["sharded", "single_queue"]),
    ("sharded/parallel", &["sharded", "parallel"]),
];

/// Sections of a `stealing` row that carry Measured-shaped objects.
const STEAL_SECTIONS: &[(&str, &[&str])] = &[
    ("single_queue", &["single_queue"]),
    ("parallel", &["parallel"]),
    ("stealing", &["stealing"]),
    ("stealing_spill", &["stealing_spill"]),
];

/// Sections of a `cluster` row (the real paper use case per engine).
const CLUSTER_SECTIONS: &[(&str, &[&str])] = &[
    ("serial", &["serial"]),
    ("sharded", &["sharded"]),
    ("stealing", &["stealing"]),
    ("stealing_spill", &["stealing_spill"]),
];

fn lookup<'a>(row: &'a Json, path: &[&str]) -> Option<&'a Json> {
    let mut cur = row;
    for &key in path {
        cur = cur.get(key)?;
    }
    Some(cur)
}

fn metric(row: &Json, path: &[&str], name: &str) -> Option<f64> {
    lookup(row, path)?.get(name)?.as_f64()
}

fn rows_of<'a>(doc: &'a Json, key: &str) -> Vec<(String, &'a Json)> {
    let Some(Json::Array(rows)) = doc.get(key) else {
        return Vec::new();
    };
    rows.iter()
        .filter_map(|r| {
            r.get("name")
                .and_then(|n| n.as_str())
                .map(|n| (n.to_string(), r))
        })
        .collect()
}

/// Tallies of a comparison pass: sections warned about (>10% slower)
/// and sections that fail the gate (>15% slower).
#[derive(Default)]
struct Tally {
    warned: u32,
    gated: u32,
}

/// Diff the Measured-shaped `sections` of every named row under `key`,
/// comparing events/sec (regression-tracked) and ms/tick (printed).
fn compare_measured(baseline: &Json, fresh: &Json, key: &str,
                    sections: &[(&str, &[&str])]) -> Tally {
    let base_rows = rows_of(baseline, key);
    let fresh_rows = rows_of(fresh, key);
    let mut tally = Tally::default();
    if fresh_rows.is_empty() {
        return tally;
    }
    println!("\n[{key}]");
    println!("{:<22} {:<22} {:>14} {:>14} {:>8}", "row", "section",
             "base ev/s", "fresh ev/s", "delta");
    println!("{}", "-".repeat(84));
    for (name, fresh_row) in fresh_rows {
        let Some((_, base_row)) =
            base_rows.iter().find(|(n, _)| *n == name)
        else {
            println!("{name:<22} (new row, no baseline)");
            continue;
        };
        for &(label, path) in sections {
            let (Some(b), Some(f)) = (
                metric(base_row, path, "events_per_sec"),
                metric(fresh_row, path, "events_per_sec"),
            ) else {
                continue;
            };
            let delta = if b > 0.0 { (f - b) / b * 100.0 } else { 0.0 };
            let mark = if delta < -GATE_PCT {
                tally.warned += 1;
                tally.gated += 1;
                "  <-- REGRESSION (gate)"
            } else if delta < -WARN_PCT {
                tally.warned += 1;
                "  <-- REGRESSION"
            } else {
                ""
            };
            println!("{name:<22} {label:<22} {b:>14.0} {f:>14.0} \
                      {delta:>+7.1}%{mark}");
            if let (Some(bm), Some(fm)) = (
                metric(base_row, path, "ms_per_tick"),
                metric(fresh_row, path, "ms_per_tick"),
            ) {
                let dm = if bm > 0.0 { (fm - bm) / bm * 100.0 } else { 0.0 };
                println!("{:<22} {:<22} {bm:>11.4} ms {fm:>11.4} ms \
                          {dm:>+7.1}%", "", "  ms/tick");
            }
        }
        // Recorder-memory trajectory (stealing rows): warn-only.
        for bytes_metric in ["recorder_bytes_in_memory",
                             "recorder_spill_file_bytes"] {
            let (Some(b), Some(f)) = (
                base_row.get(bytes_metric).and_then(|v| v.as_f64()),
                fresh_row.get(bytes_metric).and_then(|v| v.as_f64()),
            ) else {
                continue;
            };
            if b == f {
                continue;
            }
            let delta = if b > 0.0 {
                (f - b) / b * 100.0
            } else {
                f64::INFINITY
            };
            let mark = if delta > WARN_PCT { "  <-- GREW (warn-only)" }
                       else { "" };
            println!("{name:<22} {bytes_metric:<22} {b:>14.0} {f:>14.0} \
                      {delta:>+7.1}%{mark}");
        }
    }
    tally
}

/// Diff the broker policy×scenario rows: cost and makespan are the
/// broker's figures of merit (events/sec is noise at this size).
/// Always warn-only.
fn compare_broker(baseline: &Json, fresh: &Json) -> u32 {
    let base_rows = rows_of(baseline, "broker");
    let fresh_rows = rows_of(fresh, "broker");
    if fresh_rows.is_empty() {
        return 0;
    }
    println!("\n{:<28} {:>12} {:>12} {:>8}", "broker row", "base", "fresh",
             "delta");
    println!("{}", "-".repeat(64));
    let mut regressions = 0u32;
    for (name, row) in fresh_rows {
        let Some((_, base_row)) =
            base_rows.iter().find(|(n, _)| *n == name)
        else {
            println!("{name:<28} (new row, no baseline)");
            continue;
        };
        for metric_name in ["makespan_s", "cost_usd",
                            "preempt_recovered"] {
            let (Some(b), Some(f)) = (
                base_row.get(metric_name).and_then(|v| v.as_f64()),
                row.get(metric_name).and_then(|v| v.as_f64()),
            ) else {
                continue;
            };
            if b == f {
                continue; // deterministic scenarios: only drift matters
            }
            // A metric growing from a zero baseline (e.g. a formerly
            // free run starting to cost money) is an unbounded
            // regression, not a 0% one.
            let delta = if b != 0.0 {
                (f - b) / b * 100.0
            } else {
                f64::INFINITY
            };
            // A scenario getting >10% slower or pricier is a
            // regression in the broker's own currency.
            let mark = if metric_name != "preempt_recovered"
                && delta > WARN_PCT
            {
                regressions += 1;
                "  <-- REGRESSION"
            } else {
                ""
            };
            println!("{name:<28} {b:>12.4} {f:>12.4} {delta:>+7.1}% \
                      ({metric_name}){mark}");
        }
    }
    regressions
}

/// Diff the WAN-chaos rows (`key` is `"chaos"` or `"chaos_sweep"` —
/// both sections share the row shape): recovery overhead (chaos
/// makespan over the fault-free reference) and completed-jobs/sec.
/// Always warn-only — the rows mix simulated recovery behaviour with
/// wall-clock throughput, so they chart the self-healing trajectory
/// without ever gating CI.
fn compare_chaos(baseline: &Json, fresh: &Json, key: &str) -> u32 {
    let base_rows = rows_of(baseline, key);
    let fresh_rows = rows_of(fresh, key);
    if fresh_rows.is_empty() {
        return 0;
    }
    println!("\n{:<28} {:>12} {:>12} {:>8}", format!("{key} row"),
             "base", "fresh", "delta");
    println!("{}", "-".repeat(64));
    let mut regressions = 0u32;
    for (name, row) in fresh_rows {
        let Some((_, base_row)) =
            base_rows.iter().find(|(n, _)| *n == name)
        else {
            println!("{name:<28} (new row, no baseline)");
            continue;
        };
        for metric_name in ["recovery_overhead", "completed_jobs_per_sec",
                            "messages_retransmitted",
                            "quarantine_windows"] {
            let (Some(b), Some(f)) = (
                base_row.get(metric_name).and_then(|v| v.as_f64()),
                row.get(metric_name).and_then(|v| v.as_f64()),
            ) else {
                continue;
            };
            if b == f {
                continue; // deterministic chaos: only drift matters
            }
            let delta = if b != 0.0 {
                (f - b) / b * 100.0
            } else {
                f64::INFINITY
            };
            // Self-healing getting >10% more expensive (longer
            // recovery, fewer jobs through) is worth a warning; the
            // raw fault counters are informational only.
            let worse = match metric_name {
                "recovery_overhead" => delta > WARN_PCT,
                "completed_jobs_per_sec" => delta < -WARN_PCT,
                _ => false,
            };
            let mark = if worse {
                regressions += 1;
                "  <-- REGRESSION (warn-only)"
            } else {
                ""
            };
            println!("{name:<28} {b:>12.4} {f:>12.4} {delta:>+7.1}% \
                      ({metric_name}){mark}");
        }
    }
    regressions
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if args.len() != 3 {
        eprintln!("usage: bench_compare <baseline.json> <fresh.json>");
        std::process::exit(2);
    }
    let read = |path: &str| -> Json {
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("reading {path}: {e}"));
        parse(&text).unwrap_or_else(|e| panic!("parsing {path}: {e}"))
    };
    let baseline = read(&args[1]);
    let fresh = read(&args[2]);
    let gate_on = std::env::var("EVHC_BENCH_GATE")
        .map(|v| v == "1")
        .unwrap_or(false);

    if baseline
        .get("synthetic_seed")
        .and_then(|v| v.as_bool())
        .unwrap_or(false)
    {
        println!("NOTE: the committed baseline is a synthetic low-water \
                  seed;\nrefresh it with './ci.sh bench seed-baseline' \
                  on a quiet machine\nand commit the result to tighten \
                  the gate.");
    }

    let scen = compare_measured(&baseline, &fresh, "scenarios", SECTIONS);
    let steal =
        compare_measured(&baseline, &fresh, "stealing", STEAL_SECTIONS);
    let cluster =
        compare_measured(&baseline, &fresh, "cluster", CLUSTER_SECTIONS);
    let broker_regressions = compare_broker(&baseline, &fresh);
    let chaos_regressions = compare_chaos(&baseline, &fresh, "chaos")
        + compare_chaos(&baseline, &fresh, "chaos_sweep");

    let warned = scen.warned + steal.warned + cluster.warned;
    let gated = scen.gated + steal.gated + cluster.gated;
    if warned > 0 || broker_regressions > 0 || chaos_regressions > 0 {
        println!("\nWARNING: {warned} section(s) regressed by more than \
                  {WARN_PCT}% events/sec ({gated} beyond the {GATE_PCT}% \
                  gate), {broker_regressions} broker row(s) by more \
                  than {WARN_PCT}% cost/makespan and \
                  {chaos_regressions} chaos row(s) by more than \
                  {WARN_PCT}% recovery overhead (both warn-only).");
    } else {
        println!("\nno regressions beyond {WARN_PCT}%.");
    }
    if gate_on && gated > 0 {
        eprintln!("FAIL: {gated} section(s) regressed beyond {GATE_PCT}% \
                   events/sec with EVHC_BENCH_GATE=1.");
        std::process::exit(1);
    }
    if gate_on {
        println!("gate: no events/sec regression beyond {GATE_PCT}%.");
    }
}
