// Verify the Rust synth-clip generator + PJRT runtime reproduce the JAX
// build path's golden logit (artifact/runtime skew guard).
fn main() -> anyhow::Result<()> {
    let rt = evhc::runtime::ModelRuntime::load("artifacts", 1)?;
    let err = rt.verify_golden()?;
    println!("golden OK (|Δ|={err:.2e}); params={} classes={}",
             rt.entry.param_count, rt.entry.n_classes);
    let logits = rt.infer_file(7)?;
    let top = evhc::runtime::ModelRuntime::top_k(&logits, 3);
    println!("file 7 top-3: {top:?}");
    Ok(())
}
