//! Streamed trace replay: feed the bundled 100k-job arrival CSV
//! through the bounded-watermark ingestion frontend on all three
//! engines and print the replay throughput.
//!
//!     cargo run --release --example trace_replay
//!
//! The trace (`examples/sample_trace.csv`) is 250 arrival windows, 20
//! simulated seconds apart, summing to exactly 100,000 jobs — a mean
//! of 20 jobs/s against the 200-node fleet's ~22.8 jobs/s drain rate,
//! so the cluster stays busy without building an unbounded backlog.
//! The ingest watermark caps how much of the trace the frontend may
//! buffer ahead of the simulation clock; the run report's
//! `peak_buffered_jobs` proves the 100k-job file never sat in memory
//! at once. Asserted invariants: 100% completion on every engine, a
//! byte-identical `determinism_digest` across engines, and the
//! frontend-memory bound (peak buffered ≤ watermark + one arrival
//! window). Output is one line per engine — jobs/sec of replay
//! throughput and the process RSS probe — plus the shared bound.

use std::time::Instant;

use evhc::cluster::{Engine, HybridCluster, RunConfig};
use evhc::workload::trace::CsvTrace;

const TRACE: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/examples/sample_trace.csv");
const JOBS: u32 = 100_000;
const WATERMARK: u32 = 10_000;
/// Largest single arrival window in the bundled trace (jobs).
const MAX_WINDOW: u64 = 480;

/// A 200-node, 4-site carve of the paper template with quotas wide
/// enough that CLUES can actually field the fleet.
fn cluster_cfg(engine: Engine) -> RunConfig {
    let (nodes, sites) = (200u32, 4usize);
    let mut cfg = RunConfig::paper_usecase_sites(1.0, 7, sites);
    cfg.inference_every = 0;
    cfg.engine = engine;
    cfg.template.scalable.count = nodes;
    cfg.template.scalable.min_instances = 0;
    cfg.template.scalable.max_instances = nodes;
    let share = nodes / sites as u32 + 4;
    let cpus = cfg.template.worker.num_cpus;
    for site in &mut cfg.sites {
        site.quota.max_vms = share as usize + 4;
        site.quota.max_vcpus = (share + 4) * cpus;
        site.quota.max_public_ips = 8;
    }
    cfg
}

fn main() -> anyhow::Result<()> {
    evhc::util::logging::init(0);

    println!("trace:     {TRACE}");
    println!("watermark: {WATERMARK} jobs buffered ahead of the clock\n");

    let mut ref_digest = None;
    for engine in [
        Engine::Serial,
        Engine::Sharded { threads: 0 },
        Engine::Stealing { threads: 0 },
    ] {
        let mut cfg = cluster_cfg(engine);
        cfg.source = Some(Box::new(CsvTrace::open(TRACE)?));
        cfg.ingest_watermark_jobs = WATERMARK;

        let wall = Instant::now();
        let report = HybridCluster::new(cfg)?.run()?;
        let wall_s = wall.elapsed().as_secs_f64();

        assert_eq!(report.jobs_completed, JOBS,
                   "streamed replay must drain the whole trace");
        assert!(report.peak_buffered_jobs
                    <= WATERMARK as u64 + MAX_WINDOW,
                "frontend peak {} exceeds watermark {WATERMARK} + one \
                 arrival window {MAX_WINDOW}", report.peak_buffered_jobs);
        match &ref_digest {
            None => ref_digest = Some(report.determinism_digest()),
            Some(d) => assert_eq!(&report.determinism_digest(), d,
                "streamed replay diverged on {}", engine.label()),
        }

        let rss = evhc::util::rss::peak_rss_kb()
            .map(|kb| format!("{:.1} MB peak RSS", kb as f64 / 1024.0))
            .unwrap_or_else(|| "RSS probe unavailable".into());
        println!("  {:<9} {:>9.0} jobs/s  ({:.2}s wall, {} events, {})",
                 engine.label(),
                 JOBS as f64 / wall_s.max(1e-9),
                 wall_s, report.events, rss);
        println!("            peak buffered: {} jobs (of {JOBS} in the \
                  trace), makespan {}",
                 report.peak_buffered_jobs, report.makespan);
    }

    println!("\nall three engines byte-identical; the frontend never \
              buffered more than watermark + one window.");
    Ok(())
}
