//! Cloud-bursting counterfactual (paper §4.2): the same workload with and
//! without the ability to burst to AWS. The paper estimates ~4 extra
//! hours when confined to the two CESNET nodes.
//!
//!     cargo run --release --example cloud_bursting
//!
//! EVHC_SCALE shrinks the workload (default 0.25 for a quick run).

use evhc::cluster::{HybridCluster, RunConfig};

fn run(hybrid: bool, scale: f64) -> anyhow::Result<evhc::cluster::RunReport> {
    let mut cfg = RunConfig::paper_usecase(scale, 42);
    cfg.template.hybrid = hybrid;
    cfg.inference_every = 0;
    HybridCluster::new(cfg)?.run()
}

fn main() -> anyhow::Result<()> {
    evhc::util::logging::init(1);
    let scale = std::env::var("EVHC_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25);

    println!("running hybrid (CESNET + AWS burst)...");
    let hybrid = run(true, scale)?;
    println!("running on-premises only (2 CESNET nodes)...");
    let onprem = run(false, scale)?;

    assert_eq!(hybrid.jobs_completed, onprem.jobs_completed);

    let saved_h = (onprem.makespan.0 - hybrid.makespan.0) / 3600.0;
    println!("\n--- cloud bursting benefit (scale {scale}) ---");
    println!("  {:<28} {:>12} {:>12}", "", "hybrid", "on-prem only");
    println!("  {:<28} {:>12} {:>12}", "makespan",
             hybrid.makespan.hms(), onprem.makespan.hms());
    println!("  {:<28} {:>11.2}$ {:>11.2}$", "cloud cost",
             hybrid.total_cost_usd, onprem.total_cost_usd);
    println!("  {:<28} {:>12} {:>12}", "jobs",
             hybrid.jobs_completed, onprem.jobs_completed);
    println!("\n  bursting saved {saved_h:.1} h of makespan for \
              ${:.2} of public-cloud spend", hybrid.total_cost_usd);
    println!("  (paper, full scale: ~4 h saved for $0.75)");
    assert!(hybrid.makespan.0 < onprem.makespan.0,
            "bursting must shorten the makespan");
    Ok(())
}
