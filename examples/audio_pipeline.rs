//! End-to-end driver for the paper's §4 use case — the full workload
//! (3,676 audio files, four blocks) on a hybrid CESNET+AWS cluster, with
//! REAL PJRT inference on the request path: every Nth job actually runs
//! the AOT-compiled Pallas/JAX audio classifier through the xla runtime,
//! proving all three layers compose.
//!
//!     make artifacts && cargo run --release --example audio_pipeline
//!
//! Writes results/fig10_usage.csv, results/fig11_states.csv,
//! results/cost_table.csv and prints paper-vs-measured numbers (recorded
//! in EXPERIMENTS.md).
//!
//! Env knobs: EVHC_SCALE (default 1.0), EVHC_INFER_EVERY (default 25).

use evhc::cloudsim::{InjectionPlan, TransientDown};
use evhc::cluster::{HybridCluster, RunConfig};
use evhc::im::NodeRole;
use evhc::sim::SimTime;
use evhc::util::csv::Table;

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    evhc::util::logging::init(1);
    let scale = envf("EVHC_SCALE", 1.0);
    let infer_every = envf("EVHC_INFER_EVERY", 25.0) as u32;

    let mut cfg = RunConfig::paper_usecase(scale, 42);
    cfg.inference_every = infer_every;
    // The vnode-5 incident: a transient monitor flap shortly after the
    // second block starts (§4.2).
    cfg.injections = InjectionPlan {
        transient_downs: vec![TransientDown {
            node_name: "vnode-5".into(),
            start: SimTime(4800.0 * scale.max(0.02)),
            duration_secs: 300.0,
        }],
    };
    let total_jobs = cfg.workload.total_jobs();

    println!("=== EVHC end-to-end: {} jobs, real inference 1/{} ===\n",
             total_jobs, infer_every);
    let report = HybridCluster::new(cfg)?.run()?;

    // ---- timeline -----------------------------------------------------
    println!("--- milestones ---");
    for (t, m) in &report.recorder.milestones {
        println!("  {t} {m}");
    }

    // ---- figures ------------------------------------------------------
    std::fs::create_dir_all("results")?;
    let fig10 = report.recorder.fig10_usage(120.0, report.makespan);
    fig10.write("results/fig10_usage.csv")?;
    let fig11 = report.recorder.fig11_states(120.0, report.makespan);
    fig11.write("results/fig11_states.csv")?;

    let mut cost = Table::new(vec!["vm", "site", "role", "hours",
                                   "busy_hours", "cost_usd"]);
    for r in &report.per_vm {
        cost.push(vec![
            r.name.clone(),
            r.site.clone(),
            format!("{:?}", r.role),
            format!("{:.3}", r.hours),
            format!("{:.3}", r.busy_hours),
            format!("{:.4}", r.cost_usd),
        ]);
    }
    cost.write("results/cost_table.csv")?;
    println!("\nwrote results/fig10_usage.csv ({} rows), \
              results/fig11_states.csv ({} rows), results/cost_table.csv",
             fig10.len(), fig11.len());

    // ---- paper-vs-measured ---------------------------------------------
    let aws_wn: Vec<_> = report
        .per_vm
        .iter()
        .filter(|r| r.site == "AWS" && r.role == NodeRole::WorkerNode)
        .collect();
    let aws_busy: f64 = aws_wn.iter().map(|r| r.busy_hours).sum();
    let aws_paid: f64 = aws_wn.iter().map(|r| r.hours).sum();
    let deploys: Vec<f64> = report
        .deploy_times
        .iter()
        .filter(|(n, _, _)| n.starts_with("vnode-"))
        .map(|(_, r, j)| (j.0 - r.0) / 60.0)
        .collect();
    let mean_deploy = evhc::util::stats::mean(&deploys);

    println!("\n--- paper vs measured ---");
    println!("  {:<38} {:>10} {:>10}", "metric", "paper", "measured");
    let rows = [
        ("jobs completed", format!("{total_jobs}"),
         format!("{}", report.jobs_completed)),
        ("total duration", "05:40:00".to_string(),
         report.makespan.hms()),
        ("AWS WN busy (h)", "9.70".to_string(),
         format!("{aws_busy:.2}")),
        ("AWS WN paid (h)", "14.70".to_string(),
         format!("{aws_paid:.2}")),
        ("paid utilization (%)", "66".to_string(),
         format!("{:.0}", report.paid_utilization() * 100.0)),
        ("total AWS cost ($)", "0.75".to_string(),
         format!("{:.2}", report.total_cost_usd)),
        ("mean WN deploy (min)", "19-20".to_string(),
         format!("{mean_deploy:.1}")),
    ];
    for (m, p, v) in rows {
        println!("  {m:<38} {p:>10} {v:>10}");
    }

    // ---- the real compute path ------------------------------------------
    println!("\n--- PJRT hot path ---");
    println!("  inferences executed : {}", report.inferences_run);
    if report.inferences_run > 0 {
        println!("  mean latency        : {:.1} ms",
                 report.inference_wall_secs * 1e3
                     / report.inferences_run as f64);
    }
    println!("  sim events          : {} in {:.2}s wall ({:.0}x real time)",
             report.events, report.wall_secs,
             report.makespan.0 / report.wall_secs.max(1e-9));
    Ok(())
}
