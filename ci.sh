#!/usr/bin/env bash
# Staged CI pipeline.
#
#   ./ci.sh                 # full pipeline: fmt lint build doc test chaos chaos-sweep obs trace bench compare
#   ./ci.sh <stage> [...]   # run the named stage(s) in the given order
#
# Stages:
#   fmt            cargo fmt --all -- --check   (skips if rustfmt missing)
#   lint           cargo clippy -D warnings     (skips if clippy missing)
#   build          cargo build --release
#   doc            cargo doc --no-deps with RUSTDOCFLAGS="-D warnings"
#                  (skips if the toolchain is missing)
#   test           cargo test -q, plus quick re-drives of the broker
#                  scenario suite and the shard-equivalence properties
#                  with a reduced EVHC_PROPTEST_CASES budget
#   chaos          WAN chaos suite: the randomized fault-plan and
#                  regional-outage cross-engine replay properties, the
#                  health-aware placement equivalence properties and the
#                  scripted loss/quarantine tests, bounded by
#                  EVHC_PROPTEST_CASES
#   chaos-sweep    recovery-overhead frontier only (the scale bench's
#                  chaos_sweep section with its in-bench asserts, no
#                  BENCH_scale.json write), bounded by
#                  EVHC_SWEEP_POINTS (default 2 grid points here)
#   obs            observability suite: the trace/metrics byte-identity
#                  and digest-neutrality properties plus the in-crate
#                  observability unit test, bounded by
#                  EVHC_PROPTEST_CASES
#   trace          streaming-ingestion suite: SynthSource ≡ Workload
#                  digest identity, bounded-watermark cross-engine
#                  replays, trace-parser edge cases and the headroom
#                  batching knob, bounded by EVHC_PROPTEST_CASES
#   bench          scale bench in quick mode -> BENCH_scale.json; the
#                  recovery-overhead frontier (chaos sweep) section is
#                  bounded by EVHC_SWEEP_POINTS (default 4 grid points
#                  here; set 8 for the full frontier)
#   compare        diff BENCH_scale.json against the committed
#                  BENCH_baseline.json with the events/sec regression
#                  gate active (EVHC_BENCH_GATE=1: >15% fails)
#   seed-baseline  copy BENCH_scale.json over BENCH_baseline.json —
#                  explicit only, never part of the default pipeline,
#                  and refuses dirty/ephemeral checkouts
set -euo pipefail
cd "$(dirname "$0")"

stage_fmt() {
    echo "== fmt: cargo fmt --all -- --check =="
    if ! cargo fmt --version >/dev/null 2>&1; then
        echo "SKIP: rustfmt not installed (rustup component add rustfmt)"
        return 0
    fi
    cargo fmt --all -- --check
}

stage_lint() {
    echo "== lint: cargo clippy --all-targets -- -D warnings =="
    if ! cargo clippy --version >/dev/null 2>&1; then
        echo "SKIP: clippy not installed (rustup component add clippy)"
        return 0
    fi
    cargo clippy --release --all-targets -- -D warnings
}

stage_build() {
    echo "== build: cargo build --release =="
    cargo build --release
}

stage_doc() {
    # The public-API rustdoc is part of the deliverable (the
    # architecture layer links into it); broken intra-doc links or
    # malformed doc comments fail the pipeline, not just look ugly.
    echo "== doc: cargo doc --no-deps (rustdoc warnings are errors) =="
    if ! cargo --version >/dev/null 2>&1; then
        echo "SKIP: cargo not installed"
        return 0
    fi
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
}

stage_test() {
    echo "== test: cargo test -q =="
    cargo test -q

    # Tier-1 above already ran both suites in full; these quick passes
    # re-drive the determinism surfaces with a reduced property budget
    # as a cheap smoke signal for iterating on a single stage.
    echo "== test: broker scenario suite (quick mode) =="
    EVHC_PROPTEST_CASES=24 cargo test -q --test broker_policies scenario
    echo "== test: shard equivalence properties (quick mode) =="
    EVHC_PROPTEST_CASES=12 cargo test -q --test shard_equivalence prop_
    echo "== test: partitioned dispatch properties (quick mode) =="
    EVHC_PROPTEST_CASES=4 cargo test -q --test partitioned_dispatch prop_
}

stage_chaos() {
    # The full chaos property already runs under `cargo test` in tier 1;
    # this stage re-drives the WAN fault surfaces with a small bounded
    # case budget so chaos can be iterated on (and smoke-checked in the
    # default pipeline) without paying for the whole suite.
    echo "== chaos: WAN fault injection suite (quick mode) =="
    EVHC_PROPTEST_CASES=${EVHC_PROPTEST_CASES:-4} \
        cargo test -q --test broker_policies \
            chaos partition_trips_quarantine fault_plan_validation \
            cluster_completes_under regional_outage health_aware
}

stage_chaos_sweep() {
    # The frontier's health-aware-beats-sla-rank assert and per-point
    # cross-engine digest asserts run in-bench, so this doubles as the
    # adaptive-placement smoke stage; a tiny grid prefix keeps it
    # cheap in the default pipeline (the full bench stage re-walks it
    # with the larger default).
    echo "== chaos-sweep: recovery-overhead frontier (bounded) =="
    EVHC_SCALE_BENCH_QUICK=1 EVHC_SWEEP_ONLY=1 \
        EVHC_SWEEP_POINTS="${EVHC_SWEEP_POINTS:-2}" \
        cargo bench --bench scale
}

stage_obs() {
    # The observability contract: trace/metrics streams byte-identical
    # across engines, digests untouched by recording. The properties
    # also run in tier 1; this bounded re-drive makes the contract its
    # own iterable stage.
    echo "== obs: trace/metrics determinism suite (quick mode) =="
    EVHC_PROPTEST_CASES=${EVHC_PROPTEST_CASES:-2} \
        cargo test -q --test broker_policies trace_
    EVHC_PROPTEST_CASES=${EVHC_PROPTEST_CASES:-2} \
        cargo test -q --release \
            observability_is_digest_neutral_and_engine_identical
}

stage_trace() {
    # The streaming-ingestion contract: every run feeds through the
    # TraceSource layer, so SynthSource ≡ Workload identity, bounded
    # watermarks and the parser edge cases are their own iterable
    # stage. The full suite also runs under `cargo test` in tier 1.
    echo "== trace: streaming ingestion suite (quick mode) =="
    EVHC_PROPTEST_CASES=${EVHC_PROPTEST_CASES:-2} \
        cargo test -q --test trace_equivalence
}

stage_bench() {
    echo "== bench: scale bench (quick mode) =="
    EVHC_SCALE_BENCH_QUICK=1 EVHC_SWEEP_POINTS="${EVHC_SWEEP_POINTS:-4}" \
        cargo bench --bench scale
}

# Refuse to invent a baseline where it cannot be committed: on an
# ephemeral checkout (no git) or a dirty tree, a seeded baseline would
# silently disappear with the workspace — the old behaviour that made
# the perf comparison permanently inert.
check_seedable() {
    if ! git rev-parse --is-inside-work-tree >/dev/null 2>&1; then
        echo "ERROR: not a git checkout (ephemeral workspace?)." >&2
        echo "A baseline seeded here would be discarded with the" >&2
        echo "workspace. Run './ci.sh bench seed-baseline' in a real" >&2
        echo "clone and commit BENCH_baseline.json." >&2
        return 1
    fi
    if [ -n "$(git status --porcelain -uno)" ]; then
        echo "ERROR: the working tree has uncommitted changes;" >&2
        echo "refusing to seed a baseline that mixes them in. Commit" >&2
        echo "or stash first, then './ci.sh bench seed-baseline'." >&2
        return 1
    fi
    return 0
}

stage_compare() {
    echo "== compare: bench diff vs committed baseline (gated) =="
    if [ ! -f BENCH_scale.json ]; then
        echo "ERROR: no BENCH_scale.json — run './ci.sh bench' first." >&2
        return 1
    fi
    if [ ! -f BENCH_baseline.json ]; then
        echo "no committed BENCH_baseline.json." >&2
        check_seedable || return 1
        echo "Seeding the baseline from this run; COMMIT" >&2
        echo "BENCH_baseline.json to make the perf gate meaningful." >&2
        cp BENCH_scale.json BENCH_baseline.json
    fi
    EVHC_BENCH_GATE=1 cargo run --release --example bench_compare -- \
        BENCH_baseline.json BENCH_scale.json
}

stage_seed_baseline() {
    echo "== seed-baseline: BENCH_scale.json -> BENCH_baseline.json =="
    if [ ! -f BENCH_scale.json ]; then
        echo "ERROR: no BENCH_scale.json — run './ci.sh bench' first." >&2
        return 1
    fi
    check_seedable || return 1
    cp BENCH_scale.json BENCH_baseline.json
    echo "Seeded. Review and commit BENCH_baseline.json."
}

run_stage() {
    case "$1" in
        fmt)           stage_fmt ;;
        lint)          stage_lint ;;
        build)         stage_build ;;
        doc)           stage_doc ;;
        test)          stage_test ;;
        chaos)         stage_chaos ;;
        chaos-sweep)   stage_chaos_sweep ;;
        obs)           stage_obs ;;
        trace)         stage_trace ;;
        bench)         stage_bench ;;
        compare)       stage_compare ;;
        seed-baseline) stage_seed_baseline ;;
        *)
            echo "unknown stage: $1" >&2
            echo "stages: fmt lint build doc test chaos chaos-sweep" \
                 "obs trace bench compare seed-baseline" >&2
            return 2
            ;;
    esac
}

if [ "$#" -eq 0 ]; then
    set -- fmt lint build doc test chaos chaos-sweep obs trace bench compare
fi
for stage in "$@"; do
    run_stage "$stage"
done
echo "== ci: all stages passed =="
