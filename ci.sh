#!/usr/bin/env bash
# CI entry point: tier-1 verify, then the scheduling-scale bench in
# quick mode (writes BENCH_scale.json at the repo root so every run
# leaves a perf datapoint behind).
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== perf: scale bench (quick mode) =="
EVHC_SCALE_BENCH_QUICK=1 cargo bench --bench scale

echo "== done; BENCH_scale.json =="
cat BENCH_scale.json
