#!/usr/bin/env bash
# CI entry point: tier-1 verify, then the scheduling-scale bench in
# quick mode (writes BENCH_scale.json at the repo root so every run
# leaves a perf datapoint behind), then a warn-only diff against the
# committed BENCH_baseline.json.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

# Tier-1 above already ran the full broker suite; this quick pass
# re-drives just the scenario-replay tests (the broker's determinism
# surface) with a reduced property budget as a cheap smoke signal.
echo "== broker: scenario suite (quick mode) =="
EVHC_PROPTEST_CASES=24 cargo test -q --test broker_policies scenario

echo "== perf: scale bench (quick mode; includes the broker section) =="
EVHC_SCALE_BENCH_QUICK=1 cargo bench --bench scale

echo "== perf: baseline comparison (warn-only) =="
if [ -f BENCH_baseline.json ]; then
    cargo run --release --example bench_compare -- \
        BENCH_baseline.json BENCH_scale.json || true
else
    # On an ephemeral checkout this seed disappears with the workspace:
    # the diff step stays inert until someone commits the seeded file.
    echo "WARNING: no BENCH_baseline.json committed — seeding it from"
    echo "this run. COMMIT BENCH_baseline.json to activate the perf"
    echo "comparison; until then this step compares nothing."
    cp BENCH_scale.json BENCH_baseline.json
fi

echo "== done; BENCH_scale.json =="
cat BENCH_scale.json
