"""AOT export pipeline: HLO text validity + manifest golden values."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    outdir = str(tmp_path_factory.mktemp("artifacts"))
    entries = aot.export(outdir, batches=[1])
    return outdir, entries


def test_hlo_text_is_parseable_hlo(exported):
    outdir, entries = exported
    path = os.path.join(outdir, entries[0]["path"])
    text = open(path).read()
    assert "HloModule" in text
    assert "ENTRY" in text
    # Parameters were folded into constants: the ENTRY computation takes
    # only the spectrogram batch (subcomputations may take more).
    entry = text[text.index("ENTRY"):]
    entry = entry[:entry.index("\n}")]
    assert entry.count("parameter(0)") == 1
    assert "parameter(1)" not in entry
    # Weights must be materialized, not elided (the `{...}` footgun).
    assert "constant({...})" not in text


def test_hlo_contains_expected_shapes(exported):
    outdir, entries = exported
    text = open(os.path.join(outdir, entries[0]["path"])).read()
    # Input spectrogram and 527-way logits both appear in the module.
    assert f"f32[1,{model.N_FRAMES},{model.N_BINS}]" in text
    assert f"f32[1,{model.N_CLASSES}]" in text


def test_manifest_format_and_golden(exported):
    outdir, entries = exported
    lines = open(os.path.join(outdir, "MANIFEST.txt")).read().splitlines()
    assert len(lines) == len(entries)
    fields = lines[0].split()
    assert len(fields) == 8
    assert fields[0] == "audio_classifier_b1"
    assert int(fields[2]) == 1
    assert int(fields[5]) == model.N_CLASSES
    # Golden logit must match a fresh forward with the fixed-seed params.
    params = model.init_params()
    clip = jnp.asarray(model.synth_clip(0, batch=1))
    want = float(model.forward(params, clip)[0, 0])
    assert abs(float(fields[7]) - want) < 1e-4


def test_export_is_reproducible(exported, tmp_path):
    """Two exports of the same batch produce identical HLO text."""
    outdir, entries = exported
    first = open(os.path.join(outdir, entries[0]["path"])).read()
    again_dir = str(tmp_path)
    aot.export(again_dir, batches=[1])
    second = open(os.path.join(again_dir, entries[0]["path"])).read()
    assert first == second
