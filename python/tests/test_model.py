"""L2 correctness: audio-classifier forward pass, frontend, synth clips."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params()


def test_param_count_reported(params):
    # conv stacks + fc + head + the constant filterbank
    n = model.param_count(params)
    assert n > 500_000  # real network, not a stub
    assert n == model.param_count(model.init_params())  # deterministic


def test_forward_shapes(params):
    for b in (1, 3):
        spec = jnp.asarray(model.synth_clip(0, batch=b))
        logits = model.forward(params, spec)
        assert logits.shape == (b, model.N_CLASSES)


def test_forward_matches_pure_jnp_oracle(params):
    spec = jnp.asarray(model.synth_clip(42, batch=2))
    got = model.forward(params, spec)
    want = model.forward_ref(params, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_forward_deterministic(params):
    spec = jnp.asarray(model.synth_clip(7))
    a = np.asarray(model.forward(params, spec))
    b = np.asarray(model.forward(params, spec))
    np.testing.assert_array_equal(a, b)


def test_batch_consistency(params):
    """Batched forward must equal per-item forward (batch invariance)."""
    spec = jnp.asarray(model.synth_clip(5, batch=4))
    batched = np.asarray(model.forward(params, spec))
    for i in range(4):
        single = np.asarray(model.forward(params, spec[i:i + 1]))
        np.testing.assert_allclose(batched[i], single[0], rtol=1e-4,
                                   atol=1e-4)


def test_mel_filterbank_properties():
    fb = model.mel_filterbank()
    assert fb.shape == (model.N_BINS, model.N_MELS)
    assert (fb >= 0).all()
    # Every filter has support and band centres increase monotonically.
    assert (fb.sum(axis=0) > 0).all()
    centres = fb.argmax(axis=0)
    assert (np.diff(centres) >= 0).all()


def test_synth_clip_deterministic_and_distinct():
    a = model.synth_clip(1)
    b = model.synth_clip(1)
    c = model.synth_clip(2)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert (a >= 0).all()  # power spectrogram is non-negative


def test_different_clips_give_different_logits(params):
    la = np.asarray(model.forward(params, jnp.asarray(model.synth_clip(1))))
    lb = np.asarray(model.forward(params, jnp.asarray(model.synth_clip(2))))
    assert np.abs(la - lb).max() > 1e-3
