"""L1 correctness: Pallas GEMM kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compute layer: hypothesis
sweeps shapes/dtypes/activations and asserts allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import (
    ACTIVATIONS,
    matmul_bias_act,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import matmul_bias_act_ref

RTOL = 1e-4  # blocked-K accumulation reassociates float sums
ATOL = 1e-4

def _tols(act):
    """Per-activation tolerances.

    The log epilogue is ill-conditioned right at its eps-clamp: a 1e-7
    reassociation difference around x=0 moves log(max(x,0)+1e-6) by ~1e-1.
    Real callers (the mel frontend) feed non-negative spectrogram x
    filterbank products, far from the clamp; for the randomized sweep we
    accept a looser absolute tolerance there.
    """
    if act == "log":
        return dict(rtol=1e-3, atol=5e-3)
    return dict(rtol=RTOL, atol=ATOL)


def _rand(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape), dtype)


@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1),
    (4, 7, 9),
    (8, 128, 128),
    (128, 128, 128),
    (96, 257, 64),      # the mel-frontend shape
    (200, 300, 527),    # the classifier-head-ish shape
    (130, 129, 131),    # just past one tile in every dim
])
@pytest.mark.parametrize("act", sorted(ACTIVATIONS))
def test_matches_ref_fixed_shapes(m, k, n, act):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    x, w = _rand(rng, (m, k)), _rand(rng, (k, n))
    b = _rand(rng, (n,))
    got = matmul_bias_act(x, w, b, activation=act)
    want = matmul_bias_act_ref(x, w, b, activation=act)
    np.testing.assert_allclose(got, want, **_tols(act))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 160),
    k=st.integers(1, 160),
    n=st.integers(1, 160),
    act=st.sampled_from(sorted(ACTIVATIONS)),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_hypothesis(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, (m, k)), _rand(rng, (k, n))
    b = _rand(rng, (n,))
    got = matmul_bias_act(x, w, b, activation=act)
    want = matmul_bias_act_ref(x, w, b, activation=act)
    np.testing.assert_allclose(got, want, **_tols(act))


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_bf16_inputs_f32_accumulation(m, k, n, seed):
    """bf16 operands accumulate in f32 — matches a bf16-cast oracle."""
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k), jnp.bfloat16)
    w = _rand(rng, (k, n), jnp.bfloat16)
    got = matmul_bias_act(x, w, activation="none", out_dtype=jnp.float32)
    want = matmul_bias_act_ref(x, w, activation="none",
                               out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_no_bias_means_zero_bias():
    rng = np.random.default_rng(7)
    x, w = _rand(rng, (16, 32)), _rand(rng, (32, 24))
    got = matmul_bias_act(x, w)
    want = matmul_bias_act_ref(x, w, jnp.zeros((24,), jnp.float32))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("bm,bn,bk", [(32, 128, 128), (64, 128, 256),
                                      (8, 128, 128)])
def test_tile_size_invariance(bm, bn, bk):
    """Result must not depend on the tiling (up to float reassociation)."""
    rng = np.random.default_rng(11)
    x, w = _rand(rng, (100, 300)), _rand(rng, (300, 150))
    b = _rand(rng, (150,))
    base = matmul_bias_act(x, w, b, activation="relu")
    tiled = matmul_bias_act(x, w, b, activation="relu", bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(base, tiled, rtol=RTOL, atol=ATOL)


def test_relu_is_nonnegative():
    rng = np.random.default_rng(3)
    x, w = _rand(rng, (64, 64)), _rand(rng, (64, 64))
    out = np.asarray(matmul_bias_act(x, w, activation="relu"))
    assert (out >= 0).all()


def test_log_epilogue_finite_on_zero_input():
    """log epilogue clamps at eps — zero rows must stay finite."""
    x = jnp.zeros((8, 16), jnp.float32)
    w = jnp.ones((16, 8), jnp.float32)
    out = np.asarray(matmul_bias_act(x, w, activation="log"))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, np.log(1e-6), rtol=1e-5)


def test_shape_validation():
    x = jnp.zeros((4, 5), jnp.float32)
    w = jnp.zeros((6, 7), jnp.float32)
    with pytest.raises(ValueError, match="inner dims"):
        matmul_bias_act(x, w)
    with pytest.raises(ValueError, match="unknown activation"):
        matmul_bias_act(x, jnp.zeros((5, 7), jnp.float32),
                        activation="gelu")
    with pytest.raises(ValueError, match="bias shape"):
        matmul_bias_act(x, jnp.zeros((5, 7), jnp.float32),
                        jnp.zeros((8,), jnp.float32))
    with pytest.raises(ValueError, match="2-D"):
        matmul_bias_act(jnp.zeros((2, 3, 4), jnp.float32),
                        jnp.zeros((4, 5), jnp.float32))


def test_vmem_footprint_within_budget():
    """Default tiling must fit comfortably in a 16 MiB VMEM (DESIGN §Perf)."""
    fp = vmem_footprint_bytes(128, 128, 128, 4)
    assert fp == 128 * 128 * 4 * 3 + 128 * 4
    assert fp < 16 * 1024 * 1024 // 8  # < 1/8 of VMEM: double-buffer room


def test_mxu_utilization_estimate():
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    # 96x257x64 mel frontend: padding waste is bounded
    u = mxu_utilization_estimate(96, 257, 64)
    assert 0.3 < u < 1.0
    assert mxu_utilization_estimate(1, 1, 1) == pytest.approx(
        1.0 / (8 * 128 * 128))
