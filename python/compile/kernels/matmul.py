"""L1 Pallas kernel: tiled GEMM with fused epilogue (bias + activation).

This is the compute hot-spot of the audio-classifier model (every conv is
lowered to an im2col GEMM, and the mel frontend and dense head are GEMMs
too), written as a Pallas kernel so the whole model's FLOPs flow through
one well-tiled primitive.

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * the grid walks (M/bm, N/bn, K/bk); each (i, j) output tile lives in
    VMEM for the whole K loop (grid revisiting semantics), accumulating
    partial products in f32,
  * block sizes default to 128 — MXU-systolic-array aligned,
  * bias add + activation are fused into the epilogue on the *last* K step
    so the activation never round-trips to HBM.

CPU note: ``interpret=True`` is mandatory here — real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Interpret mode
lowers to plain HLO, which is exactly what the Rust runtime loads.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Epilogues available to callers. Kept as a dict of jnp-level functions so
# the same table drives both the kernel and the pure-jnp oracle in ref.py.
ACTIVATIONS = {
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    # log-compression epilogue used by the mel frontend: log(max(x,0) + eps)
    "log": lambda x: jnp.log(jnp.maximum(x, 0.0) + 1e-6),
}


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str, n_k: int):
    """One (bm, bn) output tile at one (i, j, k) grid step.

    The output tile is revisited across the K grid dimension, so it acts as
    the f32 accumulator; bias + activation are applied in place on the last
    K step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-shaped partial product, accumulated in f32 regardless of the
    # input dtype (bf16 inputs still accumulate exactly).
    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = ACTIVATIONS[activation](acc)


def _pad_to(a: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - a.shape[0], cols - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


@functools.partial(
    jax.jit, static_argnames=("activation", "bm", "bn", "bk", "out_dtype"))
def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    activation: str = "none",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
) -> jax.Array:
    """Compute ``act(x @ w + b)`` with a tiled Pallas kernel.

    Args:
      x: (M, K) input.
      w: (K, N) weights.
      b: optional (N,) bias; zeros when omitted.
      activation: one of ``ACTIVATIONS`` keys.
      bm/bn/bk: tile sizes; inputs are zero-padded up to tile multiples and
        the result is sliced back, so arbitrary shapes are accepted.
      out_dtype: result dtype (defaults to x.dtype).

    Returns:
      (M, N) array equal to ``ACTIVATIONS[activation](x @ w + b)``.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(
            f"matmul_bias_act expects 2-D operands, got {x.shape} @ {w.shape}")
    if x.shape[1] != w.shape[0]:
        raise ValueError(f"inner dims mismatch: {x.shape} @ {w.shape}")
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")

    m, k = x.shape
    _, n = w.shape
    out_dtype = out_dtype or x.dtype
    if b is None:
        b = jnp.zeros((n,), dtype=jnp.float32)
    if b.shape != (n,):
        raise ValueError(f"bias shape {b.shape} != ({n},)")

    # Clamp tiles to the (padded) problem so small layers do not pay for
    # full 128^2 tiles of zeros. For tall GEMMs (im2col of batched conv
    # layers) grow the M tile: each grid step is a sequential while-loop
    # iteration in the interpret-mode HLO (and a core dispatch on TPU), so
    # fewer/larger steps amortize the per-step overhead. 512x128 f32
    # tiles keep the working set < 1 MiB of VMEM (see
    # vmem_footprint_bytes), well inside the double-buffering budget.
    if bm == 128:
        if m >= 32768:
            bm = 2048
        elif m >= 8192:
            bm = 1024
        elif m >= 2048:
            bm = 512
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 128))
    bk = min(bk, _round_up(k, 128))

    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = _pad_to(x, mp, kp)
    wp = _pad_to(w, kp, np_)
    bp = jnp.pad(b.astype(jnp.float32), (0, np_ - n)).reshape(1, np_)

    n_k = kp // bk
    grid = (mp // bm, np_ // bn, n_k)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, activation=activation, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)

    return out[:m, :n].astype(out_dtype)


def vmem_footprint_bytes(bm: int = 128, bn: int = 128, bk: int = 128,
                         dtype_bytes: int = 4) -> int:
    """Analytic VMEM working set for one grid step (see DESIGN §Perf)."""
    x_tile = bm * bk * dtype_bytes
    w_tile = bk * bn * dtype_bytes
    o_tile = bm * bn * 4  # f32 accumulator tile (doubles as the output)
    bias = bn * 4
    return x_tile + w_tile + o_tile + bias


def mxu_utilization_estimate(m: int, k: int, n: int, bm: int = 128,
                             bn: int = 128, bk: int = 128) -> float:
    """Fraction of MXU issue slots doing useful work (padding overhead).

    The kernel pads every dim to its tile multiple; utilization is the ratio
    of real FLOPs to FLOPs issued over the padded problem. Mirrors the
    tile clamping done by matmul_bias_act.
    """
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 128))
    bk = min(bk, _round_up(k, 128))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    return (m * k * n) / float(mp * kp * np_)
