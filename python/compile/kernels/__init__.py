"""L1 Pallas kernels (interpret=True) + their pure-jnp oracles."""

from .matmul import (  # noqa: F401
    ACTIVATIONS,
    matmul_bias_act,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
