"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest (and the hypothesis sweep)
asserts that every kernel matches its oracle to tight tolerances across
shapes and dtypes. Nothing here is ever exported or run from Rust.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .matmul import ACTIVATIONS


def matmul_bias_act_ref(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    activation: str = "none",
    out_dtype=None,
) -> jax.Array:
    """Reference for kernels.matmul.matmul_bias_act (f32 accumulation)."""
    out_dtype = out_dtype or x.dtype
    acc = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if b is not None:
        acc = acc + b.astype(jnp.float32)
    return ACTIVATIONS[activation](acc).astype(out_dtype)
