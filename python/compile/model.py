"""L2: JAX audio-classifier forward pass, built on the L1 Pallas GEMM.

The paper's workload is the DEEP audio classifier (a TensorFlow model
pre-trained on Google's AudioSet, 527 classes) run once per UrbanSound8K
WAV file. We cannot ship that model, so we implement an equivalent
AudioSet-style CNN from scratch:

    power spectrogram (T=96 frames x F=257 bins)
      -> log-mel frontend      (GEMM vs a precomputed mel filterbank, log
                                epilogue fused in the kernel)
      -> 3x [conv3x3 -> ReLU -> maxpool2x2]   (convs as im2col GEMMs)
      -> global average pool
      -> dense 1024 ReLU -> dense 527 logits  (AudioSet class count)

Every FLOP-heavy op routes through ``kernels.matmul_bias_act`` so the
whole network exercises the L1 kernel; the AOT export in aot.py lowers
this exact function (with parameters baked in as constants) to the HLO
text the Rust runtime serves.

"Pre-training" is simulated: parameters are drawn from a fixed-seed
initializer, so the classifier is deterministic across the build and the
Rust side can golden-test logits.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import matmul_bias_act

# --- Model geometry (AudioSet-style, scaled for a t2.medium-class CPU) ---
N_FRAMES = 96        # spectrogram frames per clip (~1 s at 10 ms hop)
N_BINS = 257         # |rfft| bins for a 512-point FFT
N_MELS = 64          # mel bands
N_CLASSES = 527      # AudioSet label space (paper §4.1)
CONV_CHANNELS = (32, 64, 128)
HIDDEN = 1024
PARAM_SEED = 20210521  # fixed: the "pre-trained" weights


# ----------------------------------------------------------------------
# Mel filterbank (precomputed constant, folded into the HLO at export)
# ----------------------------------------------------------------------

def _hz_to_mel(f: np.ndarray | float) -> np.ndarray | float:
    return 2595.0 * np.log10(1.0 + np.asarray(f) / 700.0)


def _mel_to_hz(m: np.ndarray | float) -> np.ndarray | float:
    return 700.0 * (10.0 ** (np.asarray(m) / 2595.0) - 1.0)


def mel_filterbank(n_mels: int = N_MELS, n_bins: int = N_BINS,
                   sample_rate: int = 16000) -> np.ndarray:
    """Slaney-style triangular mel filterbank, shape (n_bins, n_mels)."""
    f_max = sample_rate / 2.0
    mels = np.linspace(_hz_to_mel(0.0), _hz_to_mel(f_max), n_mels + 2)
    hz = _mel_to_hz(mels)
    bin_freqs = np.linspace(0.0, f_max, n_bins)
    fb = np.zeros((n_bins, n_mels), dtype=np.float32)
    for m in range(n_mels):
        lo, ctr, hi = hz[m], hz[m + 1], hz[m + 2]
        up = (bin_freqs - lo) / max(ctr - lo, 1e-9)
        down = (hi - bin_freqs) / max(hi - ctr, 1e-9)
        fb[:, m] = np.maximum(0.0, np.minimum(up, down))
    # Slaney normalization: each filter integrates to ~1.
    enorm = 2.0 / (hz[2:] - hz[:-2])
    fb *= enorm[np.newaxis, :]
    return fb


# ----------------------------------------------------------------------
# Parameters
# ----------------------------------------------------------------------

def init_params(seed: int = PARAM_SEED) -> Dict[str, jax.Array]:
    """He-initialized parameters for the full network (fixed seed)."""
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}

    def he(shape: Tuple[int, ...], fan_in: int) -> np.ndarray:
        return rng.normal(0.0, math.sqrt(2.0 / fan_in),
                          size=shape).astype(np.float32)

    c_in = 1
    for i, c_out in enumerate(CONV_CHANNELS):
        # Weight rows are laid out in (c_in, kh, kw) order to match
        # conv_general_dilated_patches' feature order (see _im2col).
        params[f"conv{i}_w"] = he((3 * 3 * c_in, c_out), 3 * 3 * c_in)
        params[f"conv{i}_b"] = np.zeros((c_out,), np.float32)
        c_in = c_out
    params["fc0_w"] = he((CONV_CHANNELS[-1], HIDDEN), CONV_CHANNELS[-1])
    params["fc0_b"] = np.zeros((HIDDEN,), np.float32)
    params["head_w"] = he((HIDDEN, N_CLASSES), HIDDEN)
    params["head_b"] = np.zeros((N_CLASSES,), np.float32)
    params["mel_fb"] = mel_filterbank()
    return {k: jnp.asarray(v) for k, v in params.items()}


def param_count(params: Dict[str, jax.Array]) -> int:
    return sum(int(np.prod(p.shape)) for p in params.values())


# ----------------------------------------------------------------------
# Forward pass
# ----------------------------------------------------------------------

def _im2col(x: jax.Array, kh: int = 3, kw: int = 3) -> jax.Array:
    """(B, H, W, C) -> (B*H*W, kh*kw*C) patches with SAME padding.

    Uses conv_general_dilated_patches so patch extraction stays a cheap
    data-movement op in HLO; the FLOPs land in the Pallas GEMM.
    """
    b, h, w, _ = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    # patches: (B, H, W, C*kh*kw) with feature order (c, kh, kw). Weights
    # are stored in the same (c, kh, kw) order (init_params), so no
    # transpose/copy is needed before the GEMM — one less HBM round-trip
    # per conv layer (DESIGN §Perf L2).
    return patches.reshape(b * h * w, patches.shape[3])


def _conv_block(x: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """conv3x3(SAME) + ReLU via im2col GEMM, then 2x2 max-pool."""
    b, h, wd, _ = x.shape
    c_out = w.shape[1]
    cols = _im2col(x)
    y = matmul_bias_act(cols, w, bias, activation="relu")
    y = y.reshape(b, h, wd, c_out)
    # 2x2 max pool, stride 2 (dims are powers of two by construction)
    y = y.reshape(b, h // 2, 2, wd // 2, 2, c_out).max(axis=(2, 4))
    return y


def forward(params: Dict[str, jax.Array], spec: jax.Array) -> jax.Array:
    """Classifier forward pass.

    Args:
      params: from init_params().
      spec: (B, N_FRAMES, N_BINS) non-negative power spectrogram.

    Returns:
      (B, N_CLASSES) logits.
    """
    b = spec.shape[0]
    # Frontend: log-mel = log(spec @ mel_fb + eps), log fused in-kernel.
    x = matmul_bias_act(spec.reshape(b * N_FRAMES, N_BINS),
                        params["mel_fb"], activation="log")
    x = x.reshape(b, N_FRAMES, N_MELS, 1)

    for i in range(len(CONV_CHANNELS)):
        x = _conv_block(x, params[f"conv{i}_w"], params[f"conv{i}_b"])

    # Global average pool over time x mel.
    x = x.mean(axis=(1, 2))  # (B, C_last)

    x = matmul_bias_act(x, params["fc0_w"], params["fc0_b"],
                        activation="relu")
    logits = matmul_bias_act(x, params["head_w"], params["head_b"],
                             activation="none")
    return logits


def forward_ref(params: Dict[str, jax.Array], spec: jax.Array) -> jax.Array:
    """Pure-jnp oracle for forward() (no Pallas), used by pytest."""
    b = spec.shape[0]
    x = jnp.log(jnp.maximum(
        spec.reshape(b * N_FRAMES, N_BINS) @ params["mel_fb"], 0.0) + 1e-6)
    x = x.reshape(b, N_FRAMES, N_MELS, 1)
    for i in range(len(CONV_CHANNELS)):
        w, bias = params[f"conv{i}_w"], params[f"conv{i}_b"]
        bb, h, wd, _ = x.shape
        cols = _im2col(x)
        y = jnp.maximum(cols @ w + bias, 0.0)
        y = y.reshape(bb, h, wd, w.shape[1])
        x = y.reshape(bb, h // 2, 2, wd // 2, 2, w.shape[1]).max(axis=(2, 4))
    x = x.mean(axis=(1, 2))
    x = jnp.maximum(x @ params["fc0_w"] + params["fc0_b"], 0.0)
    return x @ params["head_w"] + params["head_b"]


# ----------------------------------------------------------------------
# Synthetic "UrbanSound" clips (stand-in for the paper's WAV files)
# ----------------------------------------------------------------------

def synth_clip(file_id: int, batch: int = 1) -> np.ndarray:
    """Deterministic synthetic power spectrogram for a given file id.

    A mixture of harmonic stacks + noise floor, shaped like urban sound
    classes; the same generator exists in Rust (workload::synth) so both
    sides can golden-test logits against each other.
    """
    out = np.empty((batch, N_FRAMES, N_BINS), np.float32)
    for bi in range(batch):
        s = _spectrogram_for(file_id + bi)
        out[bi] = s
    return out


def _spectrogram_for(file_id: int) -> np.ndarray:
    # xorshift64* PRNG — bit-for-bit identical to evhc::util::prng in Rust.
    state = (file_id * 2654435761 + 1) & 0xFFFFFFFFFFFFFFFF

    def next_u64() -> int:
        nonlocal state
        state ^= (state >> 12)
        state &= 0xFFFFFFFFFFFFFFFF
        state ^= (state << 25) & 0xFFFFFFFFFFFFFFFF
        state ^= (state >> 27)
        return (state * 0x2545F4914F6CDD1D) & 0xFFFFFFFFFFFFFFFF

    def next_f32() -> float:
        return (next_u64() >> 40) / float(1 << 24)

    f0 = 50.0 + next_f32() * 450.0          # fundamental bin frequency
    n_harm = 1 + int(next_f32() * 8)
    noise = 0.01 + next_f32() * 0.05
    am = 0.5 + next_f32() * 4.0             # amplitude modulation rate

    t = np.arange(N_FRAMES, dtype=np.float32)[:, None]
    f = np.arange(N_BINS, dtype=np.float32)[None, :]
    spec = np.full((N_FRAMES, N_BINS), noise, np.float32)
    env = (0.6 + 0.4 * np.sin(2.0 * np.pi * am * t / N_FRAMES)).astype(
        np.float32)
    for h in range(1, n_harm + 1):
        centre = f0 * h / 8000.0 * (N_BINS - 1)
        if centre >= N_BINS:
            break
        width = 1.5 + 0.5 * h
        peak = np.exp(-0.5 * ((f - centre) / width) ** 2) / h
        spec += env * peak.astype(np.float32)
    return spec


__all__: List[str] = [
    "N_FRAMES", "N_BINS", "N_MELS", "N_CLASSES", "PARAM_SEED",
    "init_params", "param_count", "forward", "forward_ref",
    "mel_filterbank", "synth_clip",
]
