"""AOT export: lower the L2 model to HLO *text* for the Rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly.

Parameters are baked into the lowered module as constants (the model is
"pre-trained"; see model.PARAM_SEED), so the Rust side passes only the
spectrogram batch and receives logits.

Usage:  python -m compile.aot --outdir ../artifacts [--batches 1,8]

Outputs (per batch size B):
    artifacts/audio_classifier_b{B}.hlo.txt
    artifacts/MANIFEST.txt       one line per artifact:
        name path batch n_frames n_bins n_classes param_count golden0
where golden0 is logits[0,0] for synth_clip(0) — the Rust integration test
checks it to guard against artifact/runtime skew.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True is load-bearing: the default elides folded
    # weight tensors as `constant({...})`, which parses back as zeros.
    return comp.as_hlo_text(print_large_constants=True)


def lower_classifier(batch: int, params=None):
    """jit-lower forward() for a fixed batch, params folded as constants."""
    params = params or model.init_params()

    def fwd(spec):
        return (model.forward(params, spec),)

    spec = jax.ShapeDtypeStruct((batch, model.N_FRAMES, model.N_BINS),
                                jnp.float32)
    return jax.jit(fwd).lower(spec)


def export(outdir: str, batches: list[int]) -> list[dict]:
    os.makedirs(outdir, exist_ok=True)
    params = model.init_params()
    entries = []
    for b in batches:
        lowered = lower_classifier(b, params)
        text = to_hlo_text(lowered)
        name = f"audio_classifier_b{b}"
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        # Golden value so Rust can verify it is running the same network.
        clip = jnp.asarray(model.synth_clip(0, batch=b))
        golden = float(model.forward(params, clip)[0, 0])
        entries.append({
            "name": name,
            "path": os.path.basename(path),
            "batch": b,
            "n_frames": model.N_FRAMES,
            "n_bins": model.N_BINS,
            "n_classes": model.N_CLASSES,
            "param_count": model.param_count(params),
            "golden0": golden,
        })
        print(f"wrote {path} ({len(text)} chars), golden0={golden:.6f}")
    manifest = os.path.join(outdir, "MANIFEST.txt")
    with open(manifest, "w") as f:
        for e in entries:
            f.write(
                f"{e['name']} {e['path']} {e['batch']} {e['n_frames']} "
                f"{e['n_bins']} {e['n_classes']} {e['param_count']} "
                f"{e['golden0']:.9e}\n")
    print(f"wrote {manifest}")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--batches", default="1,8",
                    help="comma-separated batch sizes to export")
    args = ap.parse_args()
    batches = [int(s) for s in args.batches.split(",") if s]
    export(args.outdir, batches)


if __name__ == "__main__":
    main()
